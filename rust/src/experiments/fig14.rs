//! Fig. 14 — sample genome-search output, produced by actually running the
//! AOT-compiled search over a synthetic genome via PJRT.
//!
//! Falls back to the pure-Rust packed engine when artifacts are absent
//! (flagged in the output) so the harness is usable before `make
//! artifacts` — one [`search_engine_both`](crate::genome::search_engine_both)
//! invocation covers both strands over a single packed genome, instead of
//! the old per-strand naive double scan.

use crate::genome::{self, encode::PAD, Strand};
use crate::runtime::client::geom;
use crate::runtime::{Manifest, Runtime};
use crate::sim::Rng;

/// Outcome of the fig14 run.
pub struct Fig14 {
    pub used_pjrt: bool,
    pub hits: Vec<genome::Hit>,
    pub chrom_names: Vec<&'static str>,
    pub n_patterns: usize,
}

/// Run the genome search over both strands.
///
/// * `total_bases` — synthetic genome size; * `n_patterns` — dictionary
///   size (paper: 5000; default smaller for quick runs).
pub fn run(total_bases: usize, n_patterns: usize, seed: u64) -> anyhow::Result<Fig14> {
    let g = genome::synthesize_genome(total_bases, seed);
    let mut rng = Rng::new(seed ^ 0xf19);
    let spec = genome::PatternSpec { n_patterns, ..Default::default() };
    let dict = genome::PatternDict::build(&spec, &g, &mut rng);
    let chrom_names: Vec<&'static str> = g.iter().map(|c| c.name).collect();

    let dir = Manifest::default_dir();
    // PJRT only when compiled in (`pjrt` feature) and artifacts are staged;
    // otherwise the pure-Rust reference search below covers the figure.
    let rt = if cfg!(feature = "pjrt") && dir.join("manifest.txt").exists() {
        Some(Runtime::load(&dir)?)
    } else {
        None
    };

    let mut hits = match &rt {
        Some(rt) => {
            let mut hits = Vec::new();
            for strand in [Strand::Forward, Strand::Reverse] {
                let effective = match strand {
                    Strand::Forward => dict.clone(),
                    Strand::Reverse => dict.revcomp(),
                };
                for (ci, chr) in g.iter().enumerate() {
                    for (chunk_start, mut seq) in chr.chunks(geom::CHUNK, spec.width - 1) {
                        seq.resize(geom::CHUNK, PAD);
                        let mut base = 0;
                        while base < dict.n {
                            let (patterns, lengths) = effective.block(base, geom::N_PATTERNS);
                            let (mask, _counts) = rt.genome_search(&seq, &patterns, &lengths)?;
                            genome::hits::collate_hits(
                                &mask,
                                geom::N_PATTERNS,
                                geom::CHUNK,
                                chunk_start,
                                chr.seq.len(),
                                base,
                                &lengths,
                                dict.n - base,
                                ci,
                                strand,
                                &mut hits,
                            );
                            base += geom::N_PATTERNS;
                        }
                    }
                }
            }
            hits
        }
        // Both strands through one engine invocation over one packed
        // genome; `search_naive` stays the oracle in tests.
        None => genome::search_engine_both(&g, &dict, 0),
    };
    genome::hits::dedup_hits(&mut hits);
    Ok(Fig14 { used_pjrt: rt.is_some(), hits, chrom_names, n_patterns: dict.n })
}

/// Render the Fig. 14 sample table.
pub fn render(f: &Fig14, limit: usize) -> String {
    let mut out = format!(
        "Fig 14: sample genome-search output ({} hits over {} patterns; compute path: {})\n",
        f.hits.len(),
        f.n_patterns,
        if f.used_pjrt { "PJRT (AOT pallas kernel)" } else { "pure-rust fallback" },
    );
    out.push_str(&genome::format_hits(&f.hits, &f.chrom_names, limit));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_run_finds_planted_patterns() {
        let f = run(30_000, 32, 77).unwrap();
        assert!(!f.hits.is_empty());
        // every hit's coordinates are 1-based and ordered
        for h in &f.hits {
            assert!(h.start >= 1 && h.end >= h.start);
        }
        let r = render(&f, 8);
        assert!(r.contains("seqname"));
        assert!(r.contains("pattern"));
    }

    #[test]
    fn fallback_engine_matches_naive_oracle() {
        // only meaningful on the fallback path (no pjrt, or no artifacts)
        if cfg!(feature = "pjrt") && Manifest::default_dir().join("manifest.txt").exists() {
            return;
        }
        let f = run(40_000, 48, 11).unwrap();
        assert!(!f.used_pjrt);
        let g = genome::synthesize_genome(40_000, 11);
        let mut rng = Rng::new(11 ^ 0xf19);
        let spec = genome::PatternSpec { n_patterns: 48, ..Default::default() };
        let dict = genome::PatternDict::build(&spec, &g, &mut rng);
        let mut want = genome::search_naive(&g, &dict, Strand::Forward);
        want.extend(genome::search_naive(&g, &dict, Strand::Reverse));
        genome::hits::dedup_hits(&mut want);
        assert_eq!(f.hits, want, "engine fallback must equal the two-pass naive scan");
    }

    #[test]
    fn pjrt_and_fallback_agree_when_artifacts_present() {
        let dir = Manifest::default_dir();
        if !cfg!(feature = "pjrt") || !dir.join("manifest.txt").exists() {
            return;
        }
        let f = run(25_000, 24, 3).unwrap();
        assert!(f.used_pjrt);
        // compare against the pure-rust oracle
        let g = genome::synthesize_genome(25_000, 3);
        let mut rng = Rng::new(3 ^ 0xf19);
        let spec = genome::PatternSpec { n_patterns: 24, ..Default::default() };
        let dict = genome::PatternDict::build(&spec, &g, &mut rng);
        let mut want = genome::search_naive(&g, &dict, Strand::Forward);
        want.extend(genome::search_naive(&g, &dict, Strand::Reverse));
        genome::hits::dedup_hits(&mut want);
        assert_eq!(f.hits, want);
    }
}
