//! The first beyond-paper experiment family: multi-failure regimes through
//! [`ScenarioSpec`] and the parallel batch runner (EXPERIMENTS.md
//! §Multi-failure).
//!
//! * `concurrent_k` — added execution time vs the number of concurrent
//!   node failures, one series per multi-agent strategy;
//! * `correlated` — added time vs rack-spread probability, one series per
//!   rack size;
//! * `cascade` — proactive multi-agent vs reactive checkpoint-only
//!   recovery as the probability that a migration target itself fails
//!   mid-reinstate grows.

use crate::coordinator::ftmanager::Strategy;
use crate::failure::injector::FailureProcess;
use crate::metrics::Series;
use crate::scenario::{run_sweep, CellSpec, FailureRegime, ScenarioSpec, SweepSpec};

const JOB_S: f64 = 3600.0;

/// The shared fixture at experiment scale: one sub-job per ring node.
fn spec(strategy: Strategy, predictable_frac: f64, regime: FailureRegime) -> ScenarioSpec {
    ScenarioSpec::placentia_ring16(strategy, predictable_frac, 16, regime)
}

/// Run the series' whole grid as one fused sweep (a slow `Cascade` cell no
/// longer serialises behind fast cells) and return each cell's added time
/// over the nominal job — the per-trial value is `completed_at_s`, exactly
/// what `run_batch` summarised, so the means match the old per-point loop.
fn added_s(cells: Vec<CellSpec>, trials: usize) -> Vec<f64> {
    run_sweep(&SweepSpec::new(cells, trials.max(1)))
        .iter()
        .map(|s| s.mean - JOB_S)
        .collect()
}

/// Added execution time vs number of concurrent failures (k = 1..=6).
pub fn concurrent_k(trials: usize, seed: u64) -> Series {
    let ks: Vec<usize> = (1..=6).collect();
    let strategies = [Strategy::Agent, Strategy::Core, Strategy::Hybrid];
    let cells: Vec<CellSpec> = strategies
        .iter()
        .flat_map(|&strategy| {
            ks.iter().map(move |&k| {
                CellSpec::scenario(
                    spec(
                        strategy,
                        0.9,
                        FailureRegime::ConcurrentK { k, offset_s: 900.0, spacing_s: 1.0 },
                    ),
                    seed ^ (k as u64),
                )
            })
        })
        .collect();
    let y = added_s(cells, trials);
    let mut s = Series::new(
        "Multi-failure: added time vs concurrent node failures (k)",
        "concurrent failures k",
        "added execution time (s)",
        ks.iter().map(|&k| k as f64).collect(),
    );
    for (si, strategy) in strategies.iter().enumerate() {
        s.push(strategy.name(), y[si * ks.len()..(si + 1) * ks.len()].to_vec());
    }
    s
}

/// Added execution time vs rack-spread probability, per rack size.
pub fn correlated(trials: usize, seed: u64) -> Series {
    let ps = [0.0, 0.25, 0.5, 0.75, 1.0];
    let racks = [2usize, 4, 8];
    let cells: Vec<CellSpec> = racks
        .iter()
        .flat_map(|&rack_size| {
            ps.iter().map(move |&p_spread| {
                CellSpec::scenario(
                    spec(
                        Strategy::Hybrid,
                        0.9,
                        FailureRegime::Correlated {
                            primary: FailureProcess::RandomUniform,
                            rack_size,
                            p_spread,
                            lag_s: 30.0,
                        },
                    ),
                    seed ^ ((rack_size as u64) << 8),
                )
            })
        })
        .collect();
    let y = added_s(cells, trials);
    let mut s = Series::new(
        "Multi-failure: rack-correlated failures (hybrid strategy)",
        "rack-spread probability",
        "added execution time (s)",
        ps.to_vec(),
    );
    for (ri, rack_size) in racks.iter().enumerate() {
        s.push(&format!("rack of {rack_size}"), y[ri * ps.len()..(ri + 1) * ps.len()].to_vec());
    }
    s
}

/// Proactive multi-agent vs reactive checkpoint-only recovery under
/// cascades: the migration target itself fails with probability `p_follow`.
pub fn cascade(trials: usize, seed: u64) -> Series {
    let ps = [0.0, 0.25, 0.5, 0.75];
    // (label, strategy, predictable_frac): predictable_frac 0 disables the
    // proactive path entirely, leaving pure reactive checkpoint rollback.
    let variants: [(&str, Strategy, f64); 2] = [
        ("multi-agent (proactive)", Strategy::Hybrid, 0.95),
        ("checkpoint only (reactive)", Strategy::Hybrid, 0.0),
    ];
    let cells: Vec<CellSpec> = variants
        .iter()
        .flat_map(|&(_, strategy, predictable_frac)| {
            ps.iter().enumerate().map(move |(i, &p_follow)| {
                CellSpec::scenario(
                    spec(
                        strategy,
                        predictable_frac,
                        FailureRegime::Cascade {
                            trigger: FailureProcess::RandomUniform,
                            p_follow,
                            lag_s: 5.0,
                        },
                    ),
                    seed ^ ((i as u64) << 16),
                )
            })
        })
        .collect();
    let y = added_s(cells, trials);
    let mut s = Series::new(
        "Multi-failure: cascading target failures — agents vs checkpointing",
        "cascade probability p_follow",
        "added execution time (s)",
        ps.to_vec(),
    );
    for (vi, (label, _, _)) in variants.iter().enumerate() {
        s.push(label, y[vi * ps.len()..(vi + 1) * ps.len()].to_vec());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_k_monotone_in_the_large() {
        let s = concurrent_k(12, 1);
        assert_eq!(s.series.len(), 3);
        for (name, y) in &s.series {
            // more simultaneous failures never helps
            assert!(
                y[5] >= y[0] - 1e-9,
                "{name}: k=6 ({}) should cost at least k=1 ({})",
                y[5],
                y[0]
            );
            // multi-agent strategies keep even 6 concurrent failures cheap
            // relative to a rollback (848 + 485 s)
            assert!(y.iter().all(|&v| v >= 0.0), "{name}: negative added time");
        }
    }

    #[test]
    fn cascade_reactive_dominates_proactive() {
        let s = cascade(12, 2);
        assert_eq!(s.series.len(), 2);
        let proactive = &s.series[0].1;
        let reactive = &s.series[1].1;
        // with no prediction every trigger failure rolls back; the
        // proactive line stays well below it at every cascade level
        for i in 0..proactive.len() {
            assert!(
                proactive[i] < reactive[i],
                "p={}: proactive {} >= reactive {}",
                s.x[i],
                proactive[i],
                reactive[i]
            );
        }
    }

    #[test]
    fn correlated_spread_costs_more() {
        let s = correlated(12, 3);
        for (name, y) in &s.series {
            assert!(
                y[4] >= y[0] - 1e-9,
                "{name}: certain spread ({}) should cost at least none ({})",
                y[4],
                y[0]
            );
        }
    }

    #[test]
    fn experiments_deterministic() {
        let a = concurrent_k(6, 9).to_csv();
        let b = concurrent_k(6, 9).to_csv();
        assert_eq!(a, b);
    }
}
