//! Tables 1 and 2: fault-tolerance strategy comparison on the genome
//! searching job (Placentia cluster).

use crate::cluster::{preset, ClusterPreset};
use crate::coordinator::ftmanager::Strategy;
use crate::coordinator::run::{window_row, ExperimentCfg, WindowRow};
use crate::metrics::Table;
use crate::util::fmt::{hms, hms_ms};

fn fmt_rein(s: f64) -> String {
    if s < 60.0 {
        hms_ms(s)
    } else {
        hms(s)
    }
}

fn push_row(t: &mut Table, label: &str, r: &WindowRow) {
    t.row(&[
        label.to_string(),
        r.predict_s.map(hms).unwrap_or_else(|| "-".into()),
        fmt_rein(r.reinstate_periodic_s),
        fmt_rein(r.reinstate_random_s),
        if r.overhead_periodic_s > 0.0 { hms(r.overhead_periodic_s) } else { "-".into() },
        if r.overhead_random_s > 0.0 { hms(r.overhead_random_s) } else { "-".into() },
        hms(r.total_nofail_s),
        hms(r.total_one_periodic_s),
        hms(r.total_one_random_s),
        hms(r.total_five_random_s),
    ]);
}

const HEADER: [&str; 10] = [
    "fault tolerant approach",
    "predict",
    "reinstate (periodic)",
    "reinstate (random)",
    "overheads (periodic)",
    "overheads (random)",
    "exec: no failures",
    "exec: 1 periodic/h",
    "exec: 1 random/h",
    "exec: 5 random/h",
];

/// Table 1: 1-hour job, checkpoints one hour apart, S_d = 2^19 KB, Z = 4.
pub fn table1() -> (Table, Vec<WindowRow>) {
    let cfg = ExperimentCfg::table1(preset(ClusterPreset::Placentia));
    let mut t = Table::new(
        "Table 1: comparing fault tolerant approaches between checkpoints (1 h periodicity)",
        &HEADER,
    );
    let mut rows = Vec::new();
    for s in Strategy::all_table1() {
        let r = window_row(s, &cfg);
        push_row(&mut t, s.name(), &r);
        rows.push(r);
    }
    (t, rows)
}

/// Table 2: 5-hour job; cold restart + every strategy at 1/2/4 h
/// periodicity.
pub fn table2() -> (Table, Vec<WindowRow>) {
    let mut t = Table::new(
        "Table 2: five hour job with checkpoints at 1, 2 and 4 hour periodicity",
        &HEADER,
    );
    let mut rows = Vec::new();
    // cold restart has no periodicity
    let cold_cfg = ExperimentCfg::table2(preset(ClusterPreset::Placentia), 1.0);
    let cold = window_row(Strategy::ColdRestart, &cold_cfg);
    push_row(&mut t, "cold restart (no fault tolerance)", &cold);
    rows.push(cold);
    for s in Strategy::all_table1() {
        for period in [1.0, 2.0, 4.0] {
            let cfg = ExperimentCfg::table2(preset(ClusterPreset::Placentia), period);
            let r = window_row(s, &cfg);
            push_row(&mut t, &format!("{} ({} h periodicity)", s.name(), period), &r);
            rows.push(r);
        }
    }
    (t, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::CheckpointStrategy;

    #[test]
    fn table1_shape() {
        let (t, rows) = table1();
        assert_eq!(t.n_rows(), 6);
        assert_eq!(rows.len(), 6);
        let rendered = t.render();
        assert!(rendered.contains("agent intelligence"));
        assert!(rendered.contains("01:00:00"));
    }

    #[test]
    fn table1_headline_claim() {
        // Checkpointing adds ~90% under one random failure; multi-agent ~10%.
        let (_, rows) = table1();
        let job = 3600.0;
        for r in &rows {
            let penalty = (r.total_one_random_s - job) / job;
            match r.strategy {
                Strategy::Checkpoint(_) => {
                    assert!((0.80..1.05).contains(&penalty), "{:?}: {penalty}", r.strategy)
                }
                _ => assert!(penalty < 0.15, "{:?}: {penalty}", r.strategy),
            }
        }
    }

    #[test]
    fn table1_core_fastest_multi_agent() {
        let (_, rows) = table1();
        let total = |s: Strategy| {
            rows.iter().find(|r| r.strategy == s).unwrap().total_one_periodic_s
        };
        assert!(total(Strategy::Core) < total(Strategy::Agent));
        // hybrid tracks core (Z=4 → Rule 1)
        assert!((total(Strategy::Hybrid) - total(Strategy::Core)).abs() < 2.0);
    }

    #[test]
    fn table2_shape_and_ordering() {
        let (t, rows) = table2();
        assert_eq!(t.n_rows(), 1 + 6 * 3);
        // cold restart worst at five random failures
        let cold = &rows[0];
        for r in &rows[1..] {
            assert!(
                cold.total_five_random_s > r.total_five_random_s,
                "{:?} p={}",
                r.strategy,
                r.period_h
            );
        }
        // checkpoint totals decrease with periodicity (less overhead charged)
        let ck = |p: f64| {
            rows.iter()
                .find(|r| {
                    r.strategy == Strategy::Checkpoint(CheckpointStrategy::CentralSingle)
                        && r.period_h == p
                })
                .unwrap()
                .total_five_random_s
        };
        assert!(ck(1.0) > ck(2.0) && ck(2.0) > ck(4.0));
    }

    #[test]
    fn table2_multi_agent_quarter_of_checkpointing() {
        // paper: multi-agent ≈ 1/4 the added time of checkpointing for the
        // 5 h job with five failures/hour
        let (_, rows) = table2();
        let job = 5.0 * 3600.0;
        let ck = rows
            .iter()
            .find(|r| {
                r.strategy == Strategy::Checkpoint(CheckpointStrategy::CentralSingle)
                    && r.period_h == 1.0
            })
            .unwrap();
        let core = rows
            .iter()
            .find(|r| r.strategy == Strategy::Core && r.period_h == 1.0)
            .unwrap();
        let ck_penalty = ck.total_five_random_s - job;
        let core_penalty = core.total_five_random_s - job;
        assert!(core_penalty < ck_penalty / 3.0, "ck {ck_penalty} core {core_penalty}");
    }
}
