//! The experiment harness: one entry per paper table/figure (see DESIGN.md
//! §Experiment index). Every experiment regenerates its artefact as text
//! (table, CSV series or timeline) so `biomaft experiment <id>` reproduces
//! the paper's evaluation.

pub mod ablations;
pub mod figures;
pub mod fig14;
pub mod fleet;
pub mod grayfail;
pub mod md_decisions;
pub mod multifailure;
pub mod netfault;
pub mod prediction;
pub mod registry;
pub mod rules_validation;
pub mod tables;

pub use registry::{list, run_by_id, Experiment};
