//! The molecular-dynamics decision map: which fault-tolerance approach the
//! rules select for each decomposition/scale — the application the paper's
//! Decision Making Rules section motivates.

use crate::job::molecular::{Decomposition, MdConfig};
use crate::hybrid::rules::Mover;
use crate::metrics::Table;
use crate::util::fmt::kb_pow2;

/// Build the decision map over a grid of simulation scales.
pub fn decision_map() -> Table {
    let mut t = Table::new(
        "MD fault-tolerance decision map (Rules 1-3 applied to the paper's decompositions)",
        &["decomposition", "cores", "atoms", "Z", "S_d", "S_p", "approach"],
    );
    for d in [Decomposition::Atom, Decomposition::Force, Decomposition::Spatial] {
        for (cores, atoms, steps) in [
            (8usize, 100_000usize, 500u64),
            (64, 1_000_000, 1_000),
            (512, 10_000_000, 10_000),
        ] {
            let c = MdConfig {
                decomposition: d,
                n_cores: cores,
                n_atoms: atoms,
                bytes_per_atom: 512,
                steps_per_window: steps,
            };
            let inp = c.rule_inputs();
            t.row(&[
                format!("{d:?}").to_lowercase(),
                cores.to_string(),
                atoms.to_string(),
                inp.z.to_string(),
                kb_pow2(inp.data_kb),
                kb_pow2(inp.proc_kb),
                match c.recommended() {
                    Mover::Agent => "agent".into(),
                    Mover::Core => "core".into(),
                },
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_covers_all_decompositions() {
        let t = decision_map();
        assert_eq!(t.n_rows(), 9);
        let r = t.render();
        for d in ["atom", "force", "spatial"] {
            assert!(r.contains(d), "{d}");
        }
    }

    #[test]
    fn spatial_always_core() {
        // spatial: Z = 6 <= 10 everywhere → Rule 1 → core, matching the
        // paper's observation that local-interaction decompositions suit
        // core intelligence
        let csv = decision_map().to_csv();
        for line in csv.lines().filter(|l| l.starts_with("spatial")) {
            assert!(line.ends_with("core"), "{line}");
        }
    }

    #[test]
    fn atom_decomposition_prefers_agent_until_data_blows_up() {
        let csv = decision_map().to_csv();
        let atom_rows: Vec<&str> = csv.lines().filter(|l| l.starts_with("atom")).collect();
        // at least one atom-decomposition configuration goes to agent
        assert!(atom_rows.iter().any(|l| l.ends_with("agent")), "{atom_rows:?}");
    }
}
