//! The prediction-quality experiment (Discussion, "Predicting potential
//! failures"): 29 % of faults predicted, 64 % of predictions correct, and
//! the Fig. 15 outcome-state census.
//!
//! Mechanism: each window may carry a real failure. A failure is *drifty*
//! (precursor visible to the probing process) with probability ~0.20 (plus burst-coincidence) —
//! deadlocks / power loss / instantaneous faults have no precursor, which
//! is what caps coverage. Healthy windows occasionally show transient
//! anomaly bursts (load spikes with wear signature) which the predictor
//! cannot distinguish from real drift — the false-alarm source that caps
//! precision.

use crate::cluster::core::{Core, CoreId, CoreState, HealthSample};
use crate::failure::predictor::Predictor;
use crate::failure::prober::Prober;
use crate::failure::states::{classify, OutcomeClass};
use crate::sim::{Rng, SimTime};

/// Census over windows.
#[derive(Debug, Clone, Default)]
pub struct PredictionStats {
    pub windows: usize,
    pub failures: usize,
    pub predictions: usize,
    pub predicted_failures: usize,
    pub false_alarms: usize,
    pub ideal: usize,
    pub unpredicted_failures: usize,
    /// Mean seconds from first anomalous probe to the positive prediction.
    pub mean_predict_time_s: f64,
}

impl PredictionStats {
    /// Fraction of real faults that were predicted.
    pub fn coverage(&self) -> f64 {
        self.predicted_failures as f64 / self.failures.max(1) as f64
    }

    /// Fraction of predictions followed by a real fault.
    pub fn precision(&self) -> f64 {
        self.predicted_failures as f64 / self.predictions.max(1) as f64
    }
}

/// Tunables (defaults reproduce the paper's 29 % / 64 %).
#[derive(Debug, Clone, Copy)]
pub struct PredictionCfg {
    pub windows: usize,
    pub window_s: f64,
    /// P(window carries a real failure).
    pub p_fail: f64,
    /// P(failure has a visible precursor drift).
    pub p_drifty: f64,
    /// P(healthy window shows a transient anomaly burst).
    pub p_burst: f64,
    pub probe_period_s: f64,
}

impl Default for PredictionCfg {
    fn default() -> Self {
        Self {
            windows: 4000,
            window_s: 600.0,
            p_fail: 0.5,
            p_drifty: 0.20,
            p_burst: 0.20,
            probe_period_s: 5.0,
        }
    }
}

/// Run the census with the default predictor threshold.
pub fn run_prediction(cfg: &PredictionCfg, rng: &mut Rng) -> PredictionStats {
    run_prediction_threshold(cfg, Predictor::default().threshold, rng)
}

/// Run the census with an explicit predictor threshold (ablations).
pub fn run_prediction_threshold(
    cfg: &PredictionCfg,
    threshold: f64,
    rng: &mut Rng,
) -> PredictionStats {
    let prober = Prober { period_s: cfg.probe_period_s, drift_lead_s: 60.0 };
    let predictor = Predictor { threshold, ..Default::default() };
    let mut stats = PredictionStats { windows: cfg.windows, ..Default::default() };
    let mut predict_times = Vec::new();

    for w in 0..cfg.windows {
        let mut rng = rng.fork(w as u64);
        let mut core = Core::new(CoreId(w), 64);
        // ground truth for this window
        let fail_at = if rng.chance(cfg.p_fail) {
            // leave room for the drift lead inside the window
            Some(rng.uniform(120.0, cfg.window_s))
        } else {
            None
        };
        let drifty = fail_at.is_some() && rng.chance(cfg.p_drifty);
        if let (Some(f), true) = (fail_at, drifty) {
            core.state = CoreState::Doomed { fails_at: SimTime::from_secs(f) };
        }
        // healthy-looking windows may carry a transient anomaly burst
        let burst_at = if rng.chance(cfg.p_burst) {
            Some(rng.uniform(60.0, cfg.window_s - 60.0))
        } else {
            None
        };

        let mut prediction: Option<SimTime> = None;
        let mut first_anomaly: Option<f64> = None;
        let mut t = 0.0;
        while t < cfg.window_s {
            let now = SimTime::from_secs(t);
            if let Some(f) = fail_at {
                if t >= f {
                    break; // the failure strikes; probing stops
                }
            }
            let mut s = prober.probe(&mut core, now, &mut rng);
            // overlay a transient burst (wear signature without a failure)
            if let Some(b) = burst_at {
                if (b..b + 45.0).contains(&t) {
                    let frac = (t - b) / 45.0;
                    s = HealthSample { wear: 0.35 + 0.6 * frac, soft_errors: rng.chance(0.5), ..s };
                    // replace the last sample with the burst-shaped one
                    core = replace_last(core, s);
                }
            }
            if s.wear > 0.3 && first_anomaly.is_none() {
                first_anomaly = Some(t);
            }
            if prediction.is_none() {
                if let Some(p) = predictor.evaluate(&core, now) {
                    prediction = Some(p.at);
                    if let Some(a) = first_anomaly {
                        predict_times.push(t - a);
                    }
                }
            }
            t += prober.period_s;
        }

        let failure_t = fail_at.map(SimTime::from_secs);
        match classify(prediction, failure_t) {
            OutcomeClass::Ideal => stats.ideal += 1,
            OutcomeClass::FalseAlarm => {
                stats.false_alarms += 1;
                stats.predictions += 1;
            }
            OutcomeClass::IdealPrediction => {
                stats.predicted_failures += 1;
                stats.predictions += 1;
                stats.failures += 1;
            }
            OutcomeClass::UnpredictedFailure => {
                stats.unpredicted_failures += 1;
                stats.failures += 1;
                if prediction.is_some() {
                    stats.predictions += 1;
                }
            }
        }
    }
    stats.mean_predict_time_s = if predict_times.is_empty() {
        0.0
    } else {
        predict_times.iter().sum::<f64>() / predict_times.len() as f64
    };
    stats
}

fn replace_last(mut core: Core, s: HealthSample) -> Core {
    // Core has no mutate-last API (by design); emulate by re-observing.
    core.observe(s);
    core
}

/// Render the Fig. 15-style census.
pub fn render(stats: &PredictionStats) -> String {
    format!(
        "windows: {}\nreal failures: {}\npredictions: {}\n\
         (d) ideal predictions: {}\n(c) false alarms / unstable: {}\n\
         (b) unpredicted failures: {}\n(a) quiet windows: {}\n\
         coverage: {:.1}%  (paper: 29%)\nprecision: {:.1}%  (paper: 64%)\n\
         mean anomaly->prediction time: {:.0}s  (paper: ~38s)\n",
        stats.windows,
        stats.failures,
        stats.predictions,
        stats.predicted_failures,
        stats.false_alarms,
        stats.unpredicted_failures,
        stats.ideal,
        100.0 * stats.coverage(),
        100.0 * stats.precision(),
        stats.mean_predict_time_s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> PredictionStats {
        let mut rng = Rng::new(1234);
        run_prediction(&PredictionCfg::default(), &mut rng)
    }

    // The paper-band assertions on coverage and precision live in
    // `tests/prediction_calibration.rs`: they calibrate the public
    // operating point (shared with `DetectorModel::paper_calibrated`)
    // and belong to the crate's external contract, not its internals.

    #[test]
    fn census_accounts_for_every_window() {
        let s = stats();
        assert_eq!(
            s.ideal + s.false_alarms + s.predicted_failures + s.unpredicted_failures,
            s.windows
        );
    }

    #[test]
    fn failures_split_into_predicted_and_not() {
        let s = stats();
        assert_eq!(s.failures, s.predicted_failures + s.unpredicted_failures);
    }

    #[test]
    fn render_mentions_all_classes() {
        let r = render(&stats());
        for needle in ["coverage", "precision", "false alarms", "unpredicted"] {
            assert!(r.contains(needle), "{needle}");
        }
    }

    #[test]
    fn deterministic() {
        let a = {
            let mut rng = Rng::new(7);
            run_prediction(&PredictionCfg { windows: 300, ..Default::default() }, &mut rng)
        };
        let b = {
            let mut rng = Rng::new(7);
            run_prediction(&PredictionCfg { windows: 300, ..Default::default() }, &mut rng)
        };
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.predicted_failures, b.predicted_failures);
    }
}
