//! Ablations over the design choices DESIGN.md calls out, plus the paper's
//! proposed extension (agents + checkpointing combined).

use crate::checkpoint::CheckpointStrategy;
use crate::cluster::{preset, ClusterPreset};
use crate::coordinator::combined::Combined;
use crate::coordinator::ftmanager::Strategy;
use crate::coordinator::run::{window_row, ExperimentCfg};
use crate::experiments::prediction::PredictionCfg;
use crate::metrics::Table;
use crate::scenario::{parallel_map_trials, thread_policy};
use crate::sim::Rng;
use crate::util::fmt::hms;

/// Extension table: combined strategies vs their pure components (the
/// Discussion's "first line of anticipatory response backed by
/// checkpointing").
pub fn combined_table() -> Table {
    let cfg = ExperimentCfg::table1(preset(ClusterPreset::Placentia));
    let mut t = Table::new(
        "Extension: combined multi-agent + checkpointing (expected totals, coverage 29%, precision 64%)",
        &["strategy", "exec: 1 random/h", "exec: 5 random/h", "penalty vs no-failure"],
    );
    let mut add = |name: String, one: f64, five: f64| {
        let penalty = 100.0 * (one - 3600.0) / 3600.0;
        t.row(&[name, hms(one), hms(five), format!("+{penalty:.0}%")]);
    };
    for s in [
        Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
        Strategy::Core,
    ] {
        let r = window_row(s, &cfg);
        add(s.name().to_string(), r.total_one_random_s, r.total_five_random_s);
    }
    for agent in [Strategy::Agent, Strategy::Core, Strategy::Hybrid] {
        let c = Combined { agent, backstop: CheckpointStrategy::CentralSingle };
        let r = c.window_row(&cfg);
        add(c.name(), r.total_one_random_s, r.total_five_random_s);
    }
    t
}

/// Ablation: the agent's dependency-handshake window — the knob behind the
/// Fig. 8 knee at Z = 10. The window bounds how many handshakes pay full
/// cost before overlapping kicks in, so the knee moves with it. (Pure
/// closed-form arithmetic — nothing here is worth scheduling.)
pub fn window_ablation() -> Table {
    let mut t = Table::new(
        "Ablation: agent dependency-handshake window vs reinstate time (placentia, S=2^24)",
        &["window", "Z=5", "Z=10", "Z=25", "Z=63"],
    );
    for window in [1usize, 5, 10, 20, 40] {
        let mut costs = preset(ClusterPreset::Placentia).costs.agent;
        costs.dep_window = window;
        let cells: Vec<String> = [5usize, 10, 25, 63]
            .iter()
            .map(|&z| format!("{:.3}s", costs.reinstate_s(z, 1 << 24, 1 << 24)))
            .collect();
        t.row(&[
            window.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            cells[3].clone(),
        ]);
    }
    t
}

/// Ablation: predictor threshold → coverage/precision trade-off (the knob
/// the paper's future work wants to push). Every row runs the same 2000
/// windows from its own `Rng::new(seed)` stream, so rows are independent
/// and sweep in parallel with output identical to the serial loop.
pub fn predictor_ablation(seed: u64) -> Table {
    let thresholds = [0.40, 0.48, 0.55, 0.62, 0.70];
    // 5 rows × 2000 windows is real work: the policy goes wide by default
    let threads = thread_policy(None, thresholds.len() * 2000);
    let rows = parallel_map_trials(thresholds.len(), threads, |i| {
        let mut rng = Rng::new(seed);
        let cfg = PredictionCfg { windows: 2000, ..Default::default() };
        run_with_threshold(&cfg, thresholds[i], &mut rng)
    });
    let mut t = Table::new(
        "Ablation: predictor threshold vs coverage/precision (2000 windows)",
        &["threshold", "coverage", "precision", "false alarms"],
    );
    for (thr, stats) in thresholds.iter().zip(rows) {
        t.row(&[
            format!("{thr:.2}"),
            format!("{:.1}%", 100.0 * stats.0),
            format!("{:.1}%", 100.0 * stats.1),
            stats.2.to_string(),
        ]);
    }
    t
}

/// (coverage, precision, false alarms) at a given predictor threshold.
fn run_with_threshold(cfg: &PredictionCfg, threshold: f64, rng: &mut Rng) -> (f64, f64, usize) {
    let stats =
        crate::experiments::prediction::run_prediction_threshold(cfg, threshold, rng);
    (stats.coverage(), stats.precision(), stats.false_alarms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combined_table_rows() {
        let t = combined_table();
        assert_eq!(t.n_rows(), 5);
        let r = t.render();
        assert!(r.contains("combined"));
    }

    #[test]
    fn combined_sits_between_components() {
        // rendered penalties: ckpt ~+88%, pure core ~+9%, combined between
        let r = combined_table().to_csv();
        let penalties: Vec<f64> = r
            .lines()
            .skip(1)
            .map(|l| l.rsplit('+').next().unwrap().trim_end_matches('%').parse().unwrap())
            .collect();
        let (ckpt, core) = (penalties[0], penalties[1]);
        for &c in &penalties[2..] {
            assert!(c > core && c < ckpt, "combined {c} vs ({core}, {ckpt})");
        }
    }

    #[test]
    fn window_ablation_shapes() {
        let t = window_ablation();
        assert_eq!(t.n_rows(), 5);
        // the window bounds the full-cost handshake phase: a narrower
        // window moves handshakes into the overlapped tail earlier, so at
        // large Z reinstate time grows with the window until it saturates
        let csv = t.to_csv();
        let z63: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').last().unwrap().trim_end_matches('s').parse().unwrap())
            .collect();
        assert!(z63.windows(2).all(|w| w[1] >= w[0] - 1e-9), "{z63:?}");
        // at Z=5 any window >= 5 behaves identically
        let z5: Vec<f64> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(1).unwrap().trim_end_matches('s').parse().unwrap())
            .collect();
        assert!((z5[2] - z5[4]).abs() < 1e-9, "{z5:?}");
    }

    #[test]
    fn predictor_ablation_tradeoff() {
        let t = predictor_ablation(3);
        let csv = t.to_csv();
        let rows: Vec<Vec<&str>> =
            csv.lines().skip(1).map(|l| l.split(',').collect()).collect();
        let cov: Vec<f64> =
            rows.iter().map(|r| r[1].trim_end_matches('%').parse().unwrap()).collect();
        let fa: Vec<f64> = rows.iter().map(|r| r[3].parse().unwrap()).collect();
        // lower threshold → more coverage AND more false alarms
        assert!(cov.first().unwrap() > cov.last().unwrap(), "{cov:?}");
        assert!(fa.first().unwrap() > fa.last().unwrap(), "{fa:?}");
    }
}
