//! The experiment registry: id → runner, one per paper table/figure.

use super::{ablations, fig14, figures, fleet, grayfail, md_decisions, multifailure, netfault, prediction, rules_validation, tables};
use crate::coordinator::timeline;
use crate::sim::Rng;

/// A registered experiment.
pub struct Experiment {
    pub id: &'static str,
    pub what: &'static str,
    runner: fn(trials: usize, seed: u64) -> anyhow::Result<String>,
}

/// All experiments (DESIGN.md §Experiment index).
pub fn list() -> Vec<Experiment> {
    vec![
        Experiment { id: "fig8", what: "Z vs reinstate, agent intelligence", runner: |t, s| Ok(run_series(figures::fig8(t, s))) },
        Experiment { id: "fig9", what: "Z vs reinstate, core intelligence", runner: |t, s| Ok(run_series(figures::fig9(t, s))) },
        Experiment { id: "fig10", what: "data size vs reinstate, agent", runner: |t, s| Ok(run_series(figures::fig10(t, s))) },
        Experiment { id: "fig11", what: "data size vs reinstate, core", runner: |t, s| Ok(run_series(figures::fig11(t, s))) },
        Experiment { id: "fig12", what: "process size vs reinstate, agent", runner: |t, s| Ok(run_series(figures::fig12(t, s))) },
        Experiment { id: "fig13", what: "process size vs reinstate, core", runner: |t, s| Ok(run_series(figures::fig13(t, s))) },
        Experiment { id: "fig14", what: "sample genome-search output (real PJRT compute)", runner: |_, s| {
            let f = fig14::run(120_000, 64, s)?;
            Ok(fig14::render(&f, 20))
        } },
        Experiment { id: "prediction", what: "prediction quality: coverage/precision + Fig 15 census", runner: |_, s| {
            let mut rng = Rng::new(s);
            let stats = prediction::run_prediction(&prediction::PredictionCfg::default(), &mut rng);
            Ok(prediction::render(&stats))
        } },
        Experiment { id: "fig16", what: "failure placement between checkpoints (timelines)", runner: |_, _| {
            let mut out = String::from("Fig 16(a): periodic failure at 00:14 after C_n\n");
            out.push_str(&timeline::render_timeline(&timeline::build_timeline(1.0, 1.0, &[14.0 * 60.0])));
            out.push_str("\nFig 16(b): random failure (x ~ U[0, 60) min)\n");
            out.push_str(&timeline::render_timeline(&timeline::build_timeline(1.0, 1.0, &[31.0 * 60.0 + 14.0])));
            Ok(out)
        } },
        Experiment { id: "fig17", what: "5-hour job checkpoint layouts (1/2/4 h)", runner: |_, _| {
            let mut out = String::new();
            for (label, p) in [("(b) 1 h", 1.0), ("(c) 2 h", 2.0), ("(d) 4 h", 4.0)] {
                out.push_str(&format!("Fig 17{label} periodicity\n"));
                out.push_str(&timeline::render_timeline(&timeline::build_timeline(5.0, p, &[])));
                out.push('\n');
            }
            Ok(out)
        } },
        Experiment { id: "table1", what: "FT comparison between 1 h checkpoints", runner: |_, _| Ok(tables::table1().0.render()) },
        Experiment { id: "table2", what: "5 h job, 1/2/4 h periodicity + cold restart", runner: |_, _| Ok(tables::table2().0.render()) },
        Experiment { id: "rules", what: "decision-rule validation on the genome job", runner: |_, s| Ok(rules_validation::render(&rules_validation::run(s))) },
        Experiment { id: "combined", what: "extension: agents + checkpointing combined (Discussion)", runner: |_, _| Ok(ablations::combined_table().render()) },
        Experiment { id: "ablation-window", what: "ablation: dependency-handshake window", runner: |_, _| Ok(ablations::window_ablation().render()) },
        Experiment { id: "ablation-predictor", what: "ablation: predictor threshold tradeoff", runner: |_, s| Ok(ablations::predictor_ablation(s).render()) },
        Experiment { id: "md", what: "molecular-dynamics decision map (Rules over decompositions)", runner: |_, _| Ok(md_decisions::decision_map().render()) },
        Experiment { id: "multik", what: "extension: added time vs concurrent node failures", runner: |t, s| Ok(run_series(multifailure::concurrent_k(t, s))) },
        Experiment { id: "correlated", what: "extension: rack-correlated failure spreading", runner: |t, s| Ok(run_series(multifailure::correlated(t, s))) },
        Experiment { id: "cascade", what: "extension: cascading target failures, agents vs checkpointing", runner: |t, s| Ok(run_series(multifailure::cascade(t, s))) },
        Experiment { id: "fleet", what: "fleet: mean job slowdown vs arrival rate, per strategy", runner: |t, s| Ok(run_series(fleet::fleet(t, s))) },
        Experiment { id: "fleet-contention", what: "fleet: checkpoint-server bandwidth contention under churn", runner: |t, s| Ok(run_series(fleet::fleet_contention(t, s))) },
        Experiment { id: "fleet-churn", what: "fleet: goodput under node churn (fail/repair/rejoin)", runner: |t, s| Ok(run_series(fleet::fleet_churn(t, s))) },
        Experiment { id: "fleet-scale", what: "fleet: goodput vs cluster size at ~90% load (scale ladder)", runner: |t, s| Ok(run_series(fleet::fleet_scale(t, s))) },
        Experiment { id: "netfault", what: "netfault: goodput vs message loss rate x detector accuracy", runner: |t, s| Ok(run_series(netfault::netfault(t, s))) },
        Experiment { id: "grayfail", what: "grayfail: goodput vs flap rate x detector precision", runner: |t, s| Ok(run_series(grayfail::grayfail(t, s))) },
        Experiment { id: "vopr", what: "vopr: chaos-explore spec/seed space under invariant checking", runner: |t, s| {
            let cfg = crate::scenario::VoprCfg {
                walks: t.max(1) * 8,
                base_seed: s,
                max_nodes: 32,
                max_arrivals: 512,
                ..Default::default()
            };
            let report = crate::scenario::explore(&cfg);
            let rendered = report.render();
            if report.passed() {
                Ok(rendered)
            } else {
                Err(anyhow::anyhow!(rendered))
            }
        } },
    ]
}

fn run_series(s: crate::metrics::Series) -> String {
    format!("{}\n{}", s.render(), s.to_csv())
}

/// Run one experiment by id.
pub fn run_by_id(id: &str, trials: usize, seed: u64) -> anyhow::Result<String> {
    let all = list();
    let e = all
        .iter()
        .find(|e| e.id == id)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown experiment `{id}`; available: {}",
            all.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
        ))?;
    (e.runner)(trials, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_artifact() {
        let ids: Vec<&str> = list().iter().map(|e| e.id).collect();
        for id in [
            "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig16", "fig17",
            "table1", "table2", "rules", "prediction",
        ] {
            assert!(ids.contains(&id), "{id} missing");
        }
    }

    #[test]
    fn registry_covers_multi_failure_extensions() {
        let ids: Vec<&str> = list().iter().map(|e| e.id).collect();
        for id in ["multik", "correlated", "cascade"] {
            assert!(ids.contains(&id), "{id} missing");
        }
    }

    #[test]
    fn registry_covers_fleet_family() {
        let ids: Vec<&str> = list().iter().map(|e| e.id).collect();
        for id in ["fleet", "fleet-contention", "fleet-churn", "fleet-scale"] {
            assert!(ids.contains(&id), "{id} missing");
        }
    }

    #[test]
    fn registry_covers_vopr() {
        let ids: Vec<&str> = list().iter().map(|e| e.id).collect();
        assert!(ids.contains(&"vopr"), "vopr missing");
    }

    #[test]
    fn registry_covers_netfault() {
        let ids: Vec<&str> = list().iter().map(|e| e.id).collect();
        assert!(ids.contains(&"netfault"), "netfault missing");
    }

    #[test]
    fn registry_covers_grayfail() {
        let ids: Vec<&str> = list().iter().map(|e| e.id).collect();
        assert!(ids.contains(&"grayfail"), "grayfail missing");
    }

    #[test]
    fn unknown_id_lists_available() {
        let err = run_by_id("nope", 1, 1).unwrap_err().to_string();
        assert!(err.contains("fig8"), "{err}");
    }

    #[test]
    fn quick_experiments_run() {
        for id in ["fig16", "fig17", "table1", "rules"] {
            let out = run_by_id(id, 4, 1).unwrap();
            assert!(!out.is_empty(), "{id}");
        }
    }
}
