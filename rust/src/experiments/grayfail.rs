//! The gray-failure experiment: what flapping nodes and an imperfect
//! detector cost each recovery strategy (EXPERIMENTS.md §Gray failures).
//!
//! * `grayfail` — goodput vs flap-burst rate, flap × detector precision:
//!   flapping nodes fail and rejoin in short bursts, each burst forcing a
//!   reactive rollback wave; an imperfect detector spends migrations on
//!   false alarms (`spurious_migrations`) while its lead-time jitter and
//!   missed coverage convert predicted failures back into rollbacks. The
//!   suspicion/quarantine policy is the counterweight: repeat offenders
//!   sit out a probation, so the figure shows the quarantine-off line
//!   eroding fastest as the flap rate climbs.
//!
//! The detector dimension runs the paper's calibrated operating point
//! (29 % coverage at 64 % precision — Discussion, "Predicting potential
//! failures") against the fleet default oracle (`predictable_frac = 0.9`,
//! no false alarms), which DESIGN.md §Gray-failure plane documents as
//! deliberately optimistic. Seeds follow the fleet-family convention:
//! common random numbers across variants, 2³²-spaced per x-point.

use super::fleet::{fleet_series, Variant};
use crate::checkpoint::CheckpointStrategy;
use crate::coordinator::ftmanager::Strategy;
use crate::failure::gray::DetectorModel;
use crate::metrics::Series;
use crate::scenario::{FleetMetric, FleetSpec};

/// Cluster size of the grayfail figure (ring of 32 nodes × 2 slots).
const NODES: usize = 32;

/// Apply a flap-burst rate to the spec's gray plane. Fail-slow stays off
/// so the x-axis isolates churn-by-flapping; burst shape and quarantine
/// policy stay at their calibrated defaults unless a variant says
/// otherwise.
fn flapped(mut spec: FleetSpec, rate_per_node_h: f64) -> FleetSpec {
    spec.gray.flapping.rate_per_node_h = rate_per_node_h;
    spec
}

/// Goodput vs flap-burst rate: flapping × detector precision.
pub fn grayfail(trials: usize, seed: u64) -> Series {
    let arrival = 6.0;
    let churn = 1.0;
    let variants: Vec<Variant<'_>> = vec![
        (
            "hybrid, oracle detector (90% coverage, no false alarms)",
            Box::new(move |r| {
                flapped(FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, arrival, churn), r)
            }),
        ),
        (
            "hybrid, paper detector (29% coverage, 64% precision)",
            Box::new(move |r| {
                let mut s = FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, arrival, churn);
                s.gray.detector = Some(DetectorModel::paper_calibrated());
                flapped(s, r)
            }),
        ),
        (
            "hybrid, paper detector, quarantine off",
            Box::new(move |r| {
                let mut s = FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, arrival, churn);
                s.gray.detector = Some(DetectorModel::paper_calibrated());
                s.gray.quarantine.threshold = 0;
                flapped(s, r)
            }),
        ),
        (
            "checkpoint (central, 2 streams, reactive)",
            Box::new(move |r| {
                let mut s = FleetSpec::placentia_fleet(
                    Strategy::Checkpoint(CheckpointStrategy::CentralSingle),
                    NODES,
                    arrival,
                    churn,
                );
                s.job.predictable_frac = 0.0;
                flapped(s, r)
            }),
        ),
    ];
    fleet_series(
        "Grayfail: goodput vs flap rate (32 nodes, 6 jobs/h, churn 1/node/h)",
        "flap bursts per node-hour",
        "goodput (completed compute / cluster slot-seconds)",
        &[0.0, 0.25, 0.5, 1.0, 2.0],
        &variants,
        FleetMetric::Goodput,
        trials,
        seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::fleet::run_fleet;

    #[test]
    fn grayfail_shape_and_determinism() {
        let a = grayfail(2, 9);
        assert_eq!(a.series.len(), 4);
        assert_eq!(a.x, vec![0.0, 0.25, 0.5, 1.0, 2.0]);
        for (name, y) in &a.series {
            assert_eq!(y.len(), 5, "{name}");
            assert!(y.iter().all(|v| v.is_finite()), "{name}: goodput is never NaN");
        }
        let b = grayfail(2, 9);
        assert_eq!(a.to_csv(), b.to_csv());
    }

    #[test]
    fn flapless_point_matches_the_clean_fleet() {
        // At flap rate 0.0 the oracle variant's plane is off and the cell
        // must be byte-identical to a spec that never mentions gray at all.
        let spec = flapped(FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, 6.0, 1.0), 0.0);
        assert!(spec.gray.is_off());
        let clean = FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, 6.0, 1.0);
        let a = run_fleet(&spec, 42);
        let b = run_fleet(&clean, 42);
        assert_eq!(a.events, b.events);
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.mean_slowdown.to_bits(), b.mean_slowdown.to_bits());
        assert_eq!((a.spurious_migrations, a.quarantines, a.quarantine_releases), (0, 0, 0));
        assert_eq!(a.degraded_node_s.to_bits(), 0f64.to_bits());
    }

    #[test]
    fn paper_detector_pays_false_alarms_and_quarantine_contains_flapping() {
        // The paper-calibrated variant at the top flap rate must exercise
        // the gray counters: false alarms become spurious migrations and
        // repeat flap offenders get quarantined.
        let mut spec = FleetSpec::placentia_fleet(Strategy::Hybrid, NODES, 6.0, 1.0);
        spec.gray.detector = Some(DetectorModel::paper_calibrated());
        let spec = flapped(spec, 2.0);
        let o = run_fleet(&spec, 11);
        assert!(o.spurious_migrations > 0, "paper detector never cried wolf: {o:?}");
        assert!(o.quarantines > 0, "flap bursts never crossed the threshold: {o:?}");
        assert!(o.jobs_completed > 0, "{o:?}");
    }
}
