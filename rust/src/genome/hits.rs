//! Hit records and the Fig. 14 output format:
//! `seqname start end patternID strand`.

/// Strand of a hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strand {
    Forward,
    Reverse,
}

impl Strand {
    pub fn symbol(self) -> char {
        match self {
            Strand::Forward => '+',
            Strand::Reverse => '-',
        }
    }
}

/// One search hit (coordinates are 1-based inclusive, as in the paper's
/// sample output).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hit {
    pub chrom_idx: usize,
    pub start: usize,
    pub end: usize,
    pub pattern_id: usize,
    pub strand: Strand,
}

/// Collate hits from a kernel mask block.
///
/// `mask` is row-major [n_patterns x chunk]; `chunk_start` is the chunk's
/// offset within the chromosome; `pattern_base` is the dictionary index of
/// mask row 0; rows at or beyond `n_real` are block padding.
#[allow(clippy::too_many_arguments)]
pub fn collate_hits(
    mask: &[i8],
    n_patterns: usize,
    chunk: usize,
    chunk_start: usize,
    chrom_len: usize,
    pattern_base: usize,
    lengths: &[i32],
    n_real: usize,
    chrom_idx: usize,
    strand: Strand,
    out: &mut Vec<Hit>,
) {
    debug_assert_eq!(mask.len(), n_patterns * chunk);
    for p in 0..n_patterns.min(n_real) {
        let plen = lengths[p] as usize;
        let row = &mask[p * chunk..(p + 1) * chunk];
        // The mask is overwhelmingly zero (hit density ~1e-4): scan 8 bytes
        // at a time and skip zero words — ~10x on the combining-node path
        // (EXPERIMENTS.md §Perf).
        let mut emit = |i: usize| {
            let gstart = chunk_start + i;
            let gend = gstart + plen; // exclusive
            if gend <= chrom_len {
                out.push(Hit {
                    chrom_idx,
                    start: gstart + 1, // 1-based
                    end: gend,
                    pattern_id: pattern_base + p,
                    strand,
                });
            }
        };
        let words = row.len() / 8;
        for w in 0..words {
            let bytes: [i8; 8] = row[w * 8..w * 8 + 8].try_into().unwrap();
            if u64::from_ne_bytes(bytes.map(|b| b as u8)) == 0 {
                continue;
            }
            for (b, &v) in bytes.iter().enumerate() {
                if v != 0 {
                    emit(w * 8 + b);
                }
            }
        }
        for i in words * 8..row.len() {
            if row[i] != 0 {
                emit(i);
            }
        }
    }
}

/// Deduplicate hits found twice in chunk overlaps.
pub fn dedup_hits(hits: &mut Vec<Hit>) {
    hits.sort_by_key(|h| (h.chrom_idx, h.pattern_id, h.start, h.strand.symbol() as u8));
    hits.dedup();
}

/// Render the Fig. 14 table: seqname, start, end, patternID, strand.
pub fn format_hits(hits: &[Hit], chrom_names: &[&str], limit: usize) -> String {
    let mut out = String::from("seqname  start     end       patternID   strand\n");
    for h in hits.iter().take(limit) {
        out.push_str(&format!(
            "{:<8} {:<9} {:<9} pattern{:<6} {}\n",
            chrom_names[h.chrom_idx],
            h.start,
            h.end,
            h.pattern_id,
            h.strand.symbol()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collate_finds_positions() {
        // 2 patterns x chunk 8; hits for p0 at 2, p1 at 5
        let mut mask = vec![0i8; 16];
        mask[2] = 1;
        mask[8 + 5] = 1;
        let mut hits = Vec::new();
        collate_hits(&mask, 2, 8, 100, 1000, 40, &[3, 2], 2, 0, Strand::Forward, &mut hits);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0], Hit { chrom_idx: 0, start: 103, end: 105, pattern_id: 40, strand: Strand::Forward });
        assert_eq!(hits[1].pattern_id, 41);
        assert_eq!(hits[1].start, 106);
        assert_eq!(hits[1].end, 107);
    }

    #[test]
    fn hits_beyond_chrom_len_dropped() {
        let mut mask = vec![0i8; 8];
        mask[6] = 1; // start 6 + len 5 > chrom_len 10
        let mut hits = Vec::new();
        collate_hits(&mask, 1, 8, 0, 10, 0, &[5], 1, 0, Strand::Forward, &mut hits);
        assert!(hits.is_empty());
    }

    #[test]
    fn padded_rows_ignored() {
        let mask = vec![1i8; 16]; // both rows "hit" everywhere
        let mut hits = Vec::new();
        collate_hits(&mask, 2, 8, 0, 100, 0, &[2, 2], 1, 0, Strand::Forward, &mut hits);
        assert!(hits.iter().all(|h| h.pattern_id == 0));
    }

    #[test]
    fn dedup_removes_overlap_duplicates() {
        let h = Hit { chrom_idx: 0, start: 5, end: 9, pattern_id: 1, strand: Strand::Forward };
        let mut hits = vec![h, h, Hit { start: 6, ..h }];
        dedup_hits(&mut hits);
        assert_eq!(hits.len(), 2);
    }

    #[test]
    fn fig14_format() {
        let hits = vec![Hit {
            chrom_idx: 0,
            start: 5_942_496,
            end: 5_942_511,
            pattern_id: 17,
            strand: Strand::Forward,
        }];
        let s = format_hits(&hits, &["chrI"], 10);
        assert!(s.contains("seqname"));
        assert!(s.contains("chrI"));
        assert!(s.contains("5942496"));
        assert!(s.contains("pattern17"));
        assert!(s.contains('+'));
    }
}
