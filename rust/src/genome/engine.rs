//! The packed multi-pattern search engine — single-pass, chunk-parallel.
//!
//! [`search_naive`](super::search::search_naive) rescans every chromosome
//! once **per pattern**, serially; at the paper's job size (a dictionary of
//! 5000 patterns of 15-25 nt over chromosome-scale sequences) that is
//! thousands of passes over hundreds of megabases. This engine makes the
//! paper-scale search tractable in pure Rust:
//!
//! * the genome packs to 2-bit codes with an N-run side index
//!   ([`PackedSeq`]) — 4x less memory traffic than the `i8` sequence —
//!   and each chunk decodes once into a per-worker scratch buffer that
//!   every bank then scans;
//! * the dictionary is grouped by length and compiled into **shift-and
//!   (bitap) banks**: ⌊64/m⌋ patterns of length `m` share one `u64`, so a
//!   single shift-or-and per text base advances every pattern in the bank
//!   simultaneously (see [`Bank`] for why packed bit-fields cannot
//!   interfere). Patterns longer than [`BANK_MAX_LEN`] bases take a
//!   rare-symbol-prefilter literal scan instead;
//! * work fans out as (chromosome-chunk × bank-shard) tasks through the
//!   work-stealing
//!   [`parallel_map_trials_scratch`](crate::scenario::batch::parallel_map_trials_scratch)
//!   scheduler. Each task owns the match *starts* in `[owned_start,
//!   owned_end)` and scans `max_len - 1` bases past its end, so a hit
//!   spanning a chunk boundary is found by exactly one task — no overlap
//!   dedup is needed — and task results merge by a total (chromosome,
//!   pattern, position) sort into output **byte-identical to the naive
//!   oracle at any thread count** (property-tested in
//!   `tests/genome_engine.rs`).
//!
//! Match semantics are literal symbol equality, exactly as the Pallas
//! kernel and the oracle define them: `N` matches `N`, the `PAD` sentinel
//! matches only itself (real pattern rows never contain it inside their
//! true length, and chromosomes never contain it at all). Sequences are
//! expected in `encode_seq` output space (`{PAD, A, C, G, T, N}`).

use super::data::Chromosome;
use super::encode::{PackedSeq, PAD};
use super::hits::{Hit, Strand};
use super::patterns::PatternDict;
use crate::scenario::batch::{default_threads, parallel_map_trials_scratch};

/// Longest pattern the bit-parallel banks handle (one `u64` bit-field).
pub const BANK_MAX_LEN: usize = 64;

/// Match starts owned by one chunk task. Tasks scan `max_len - 1` bases
/// beyond their owned range, so the effective chunk overlap is the classic
/// `width - 1` and every boundary-spanning hit belongs to exactly one task.
pub const CHUNK_OWNED: usize = 1 << 16;

/// Symbol space of the bank tables: `A,C,G,T,N → 0..=4`, `PAD → 5`, and a
/// never-matching slot 6 for anything outside the encoding.
const SYMBOLS: usize = 7;

#[inline]
fn sym(c: i8) -> u8 {
    match c {
        0..=4 => c as u8,
        PAD => 5,
        _ => 6,
    }
}

/// One shift-and bank: `k = ⌊64/m⌋` patterns of length `m` share a `u64`.
///
/// Pattern slot `j` occupies bits `[j·m, (j+1)·m)`; `table[s]` has bit
/// `j·m + i` set iff slot `j`'s position `i` is symbol `s`. The per-base
/// update is `d = ((d << 1) | init) & table[s]`: bit `j·m + i` of `d` is
/// live iff the last `i + 1` bases match slot `j`'s prefix, so a set bit
/// under `hit` (bit `j·m + m - 1`) is a full match ending at the current
/// base. The shift's carry out of field `j` lands exactly on field
/// `j + 1`'s start bit — which `init` sets unconditionally anyway (a match
/// may start at every base) — so packed fields never interfere and no
/// spacer bits are spent.
#[derive(Debug, Clone)]
struct Bank {
    m: usize,
    table: [u64; SYMBOLS],
    init: u64,
    hit: u64,
    /// Dictionary ids of the packed patterns, slot order.
    ids: Vec<u32>,
}

/// A pattern too long for a `u64` bank: literal compare behind a prefilter
/// probing the pattern's rarest symbol (fewest windows survive the probe).
#[derive(Debug, Clone)]
struct LongPat {
    id: u32,
    /// Pattern in symbol space (see [`sym`]).
    syms: Vec<u8>,
    /// Probe offset for the prefilter.
    probe: usize,
}

/// A dictionary compiled for one strand: banks for the bit-parallel
/// lengths, literal scans for the long tail.
#[derive(Debug, Clone)]
pub struct SearchEngine {
    banks: Vec<Bank>,
    long: Vec<LongPat>,
    /// Longest real pattern — the chunk-overlap width.
    max_len: usize,
    /// Rows the engine was compiled from (mask rows of [`Self::run_block`]).
    n_rows: usize,
    /// Zero-length rows: skipped by the chromosome search (the oracle
    /// skips empty patterns) but — matching the kernel's degenerate
    /// equality compare, where no column constrains the window — matching
    /// *every* position in [`Self::run_block`].
    empty_rows: Vec<u32>,
}

impl SearchEngine {
    /// Compile a dictionary (its rows as-is; callers pass
    /// [`PatternDict::revcomp`] for the reverse strand).
    pub fn from_dict(dict: &PatternDict) -> Self {
        Self::from_rows(&dict.matrix, &dict.lengths, dict.width)
    }

    /// Compile from a raw row-major `[n × width]` matrix + lengths — the
    /// kernel block layout, so the worker-pool CPU fallback compiles
    /// dictionary blocks directly.
    pub fn from_rows(matrix: &[i8], lengths: &[i32], width: usize) -> Self {
        let n = lengths.len();
        assert_eq!(matrix.len(), n * width, "matrix must be row-major [n x width]");
        let mut by_len: Vec<Vec<u32>> = vec![Vec::new(); BANK_MAX_LEN + 1];
        let mut long = Vec::new();
        let mut empty_rows = Vec::new();
        let mut max_len = 0usize;
        for p in 0..n {
            let m = lengths[p];
            assert!(m >= 0 && m as usize <= width, "pattern {p} length {m} out of [0, {width}]");
            let m = m as usize;
            if m == 0 {
                empty_rows.push(p as u32);
                continue;
            }
            max_len = max_len.max(m);
            if m <= BANK_MAX_LEN {
                by_len[m].push(p as u32);
            } else {
                let row = &matrix[p * width..p * width + m];
                let syms: Vec<u8> = row.iter().map(|&c| sym(c)).collect();
                let probe = rare_probe(&syms);
                long.push(LongPat { id: p as u32, syms, probe });
            }
        }
        let mut banks = Vec::new();
        for (m, ids) in by_len.iter().enumerate().skip(1) {
            for group in ids.chunks(BANK_MAX_LEN / m) {
                let mut bank =
                    Bank { m, table: [0; SYMBOLS], init: 0, hit: 0, ids: group.to_vec() };
                for (j, &id) in group.iter().enumerate() {
                    let base = j * m;
                    bank.init |= 1u64 << base;
                    bank.hit |= 1u64 << (base + m - 1);
                    let row = &matrix[id as usize * width..id as usize * width + m];
                    for (i, &c) in row.iter().enumerate() {
                        let s = sym(c) as usize;
                        if s < 6 {
                            bank.table[s] |= 1u64 << (base + i);
                        }
                    }
                }
                banks.push(bank);
            }
        }
        Self { banks, long, max_len, n_rows: n, empty_rows }
    }

    /// Schedulable units: banks plus long-tail patterns.
    fn units(&self) -> usize {
        self.banks.len() + self.long.len()
    }

    /// Run the compiled dictionary block over one chunk — the kernel's
    /// `(mask, counts)` contract (see [`search_block`] for the semantics).
    /// Compiling once and calling this per chunk is how the worker-pool
    /// fallback keeps dictionary compilation out of its task loop.
    pub fn run_block(&self, seq: &[i8]) -> (Vec<i8>, Vec<i32>) {
        let n = self.n_rows;
        let chunk = seq.len();
        let mut mask = vec![0i8; n * chunk];
        let mut counts = vec![0i32; n];
        if chunk == 0 {
            return (mask, counts);
        }
        let codes: Vec<u8> = seq.iter().map(|&c| sym(c)).collect();
        for bank in &self.banks {
            scan_bank(bank, &codes, |slot, i| {
                let p = bank.ids[slot] as usize;
                mask[p * chunk + (i + 1 - bank.m)] = 1;
                counts[p] += 1;
            });
        }
        for lp in &self.long {
            scan_long(lp, &codes, chunk, |i| {
                let p = lp.id as usize;
                mask[p * chunk + i] = 1;
                counts[p] += 1;
            });
        }
        // Zero-length rows: no column constrains the kernel's equality
        // compare, so every position "matches" — reproduced exactly.
        for &p in &self.empty_rows {
            let p = p as usize;
            mask[p * chunk..(p + 1) * chunk].fill(1);
            counts[p] = chunk as i32;
        }
        (mask, counts)
    }
}

/// Prefilter probe for a long pattern: the first offset holding the
/// pattern's rarest symbol.
fn rare_probe(syms: &[u8]) -> usize {
    let mut freq = [0u32; SYMBOLS];
    for &s in syms {
        freq[s as usize] += 1;
    }
    let rare = (0..SYMBOLS)
        .filter(|&s| freq[s] > 0)
        .min_by_key(|&s| freq[s])
        .unwrap_or(0) as u8;
    syms.iter().position(|&s| s == rare).unwrap_or(0)
}

/// Run one bank over decoded symbols, calling `on_end(slot, i)` for every
/// match ending at `codes[i]`.
#[inline]
fn scan_bank(bank: &Bank, codes: &[u8], mut on_end: impl FnMut(usize, usize)) {
    let mut d = 0u64;
    for (i, &c) in codes.iter().enumerate() {
        d = ((d << 1) | bank.init) & bank.table[c as usize];
        let mut h = d & bank.hit;
        while h != 0 {
            on_end(h.trailing_zeros() as usize / bank.m, i);
            h &= h - 1;
        }
    }
}

/// Scan one long pattern over decoded symbols, calling `on_start(i)` for
/// every match starting at `codes[i]` with `i < start_limit`.
#[inline]
fn scan_long(lp: &LongPat, codes: &[u8], start_limit: usize, mut on_start: impl FnMut(usize)) {
    let m = lp.syms.len();
    if codes.len() < m || start_limit == 0 {
        return;
    }
    let probe_sym = lp.syms[lp.probe];
    let last = (codes.len() - m).min(start_limit - 1);
    for i in 0..=last {
        if codes[i + lp.probe] == probe_sym && codes[i..i + m] == lp.syms[..] {
            on_start(i);
        }
    }
}

/// One schedulable unit of search work: a bank-shard of one strand's
/// engine over one chunk's owned match-start range.
#[derive(Debug, Clone, Copy)]
struct Task {
    chrom: usize,
    owned_start: usize,
    owned_end: usize,
    /// Index into the `(strand, engine)` slice.
    slot: usize,
    unit_lo: usize,
    unit_hi: usize,
}

fn run_task(
    engines: &[(Strand, &SearchEngine)],
    packed: &[PackedSeq],
    t: &Task,
    buf: &mut Vec<u8>,
) -> Vec<Hit> {
    let (strand, eng) = engines[t.slot];
    let seq = &packed[t.chrom];
    let owned_len = t.owned_end - t.owned_start;
    let scan_end = (t.owned_end + eng.max_len - 1).min(seq.len());
    seq.decode_range(t.owned_start, scan_end, buf);
    let mut hits = Vec::new();
    for u in t.unit_lo..t.unit_hi {
        if let Some(bank) = eng.banks.get(u) {
            let m = bank.m;
            // A match ending at codes[i] starts at i + 1 - m; keep starts
            // inside the owned range: i < owned_len + m - 1.
            let window = &buf[..buf.len().min(owned_len + m - 1)];
            scan_bank(bank, window, |slot, i| {
                let start0 = t.owned_start + i + 1 - m;
                hits.push(Hit {
                    chrom_idx: t.chrom,
                    start: start0 + 1,
                    end: start0 + m,
                    pattern_id: bank.ids[slot] as usize,
                    strand,
                });
            });
        } else {
            let lp = &eng.long[u - eng.banks.len()];
            let m = lp.syms.len();
            scan_long(lp, buf, owned_len, |i| {
                let start0 = t.owned_start + i;
                hits.push(Hit {
                    chrom_idx: t.chrom,
                    start: start0 + 1,
                    end: start0 + m,
                    pattern_id: lp.id as usize,
                    strand,
                });
            });
        }
    }
    hits
}

/// Fan (chunk × bank-shard) tasks over the work-stealing scheduler and
/// collect every task's hits (unordered; callers sort).
fn run_tasks(
    packed: &[PackedSeq],
    engines: &[(Strand, &SearchEngine)],
    threads: usize,
) -> Vec<Hit> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let n_chunks: usize = packed.iter().map(|p| p.len().div_ceil(CHUNK_OWNED)).sum();
    let mut tasks = Vec::new();
    for (slot, (_, eng)) in engines.iter().enumerate() {
        let units = eng.units();
        if units == 0 || n_chunks == 0 {
            continue;
        }
        // Shard the unit list so small genomes (few chunks) still spread
        // across workers; chromosome-scale genomes get their parallelism
        // from chunks and run one shard. The decomposition never affects
        // the output — the final sort is a total order.
        let shards = (4 * threads).div_ceil(n_chunks * engines.len()).clamp(1, units);
        for (ci, p) in packed.iter().enumerate() {
            let mut s = 0;
            while s < p.len() {
                let e = (s + CHUNK_OWNED).min(p.len());
                for sh in 0..shards {
                    let (lo, hi) = (sh * units / shards, (sh + 1) * units / shards);
                    if lo < hi {
                        tasks.push(Task {
                            chrom: ci,
                            owned_start: s,
                            owned_end: e,
                            slot,
                            unit_lo: lo,
                            unit_hi: hi,
                        });
                    }
                }
                s = e;
            }
        }
    }
    let per_task = parallel_map_trials_scratch(
        tasks.len(),
        threads,
        // one decoded chunk + the longest possible bank overlap
        || Vec::with_capacity(CHUNK_OWNED + BANK_MAX_LEN),
        |buf, i| run_task(engines, packed, &tasks[i], buf),
    );
    per_task.into_iter().flatten().collect()
}

/// Single-strand engine search. Byte-identical to
/// [`search_naive`](super::search::search_naive) — same hits in the same
/// (chromosome, pattern, position) order — at any thread count
/// (`threads == 0` ⇒ one per core).
pub fn search_engine(
    genome: &[Chromosome],
    dict: &PatternDict,
    strand: Strand,
    threads: usize,
) -> Vec<Hit> {
    let eng = match strand {
        Strand::Forward => SearchEngine::from_dict(dict),
        Strand::Reverse => SearchEngine::from_dict(&dict.revcomp()),
    };
    let packed: Vec<PackedSeq> = genome.iter().map(|c| PackedSeq::pack(&c.seq)).collect();
    let mut hits = run_tasks(&packed, &[(strand, &eng)], threads);
    hits.sort_unstable_by_key(|h| (h.chrom_idx, h.pattern_id, h.start));
    hits
}

/// Both strands in one invocation: the genome packs **once** and both
/// strand dictionaries scan the same packed chunks (fig14's fallback used
/// to re-scan — and re-revcomp the dictionary for — each strand
/// separately). Output order is exactly what
/// [`dedup_hits`](super::hits::dedup_hits) produces from the two-pass
/// naive scan, so `naive(F) ++ naive(R) |> dedup_hits` callers get
/// byte-identical results.
pub fn search_engine_both(genome: &[Chromosome], dict: &PatternDict, threads: usize) -> Vec<Hit> {
    let fwd = SearchEngine::from_dict(dict);
    let rev = SearchEngine::from_dict(&dict.revcomp());
    let packed: Vec<PackedSeq> = genome.iter().map(|c| PackedSeq::pack(&c.seq)).collect();
    let mut hits =
        run_tasks(&packed, &[(Strand::Forward, &fwd), (Strand::Reverse, &rev)], threads);
    hits.sort_unstable_by_key(|h| (h.chrom_idx, h.pattern_id, h.start, h.strand.symbol() as u8));
    hits
}

/// Pure-Rust drop-in for the AOT `genome_search` executable: one chunk
/// against one kernel-layout dictionary block, same semantics bit for bit.
/// `mask[p * chunk + i] = 1` iff pattern `p` matches the window starting at
/// `i` (literal symbol equality — the all-`PAD` padding rows of short
/// blocks match only inside the chunk's `PAD` tail, exactly as the
/// kernel's equality compare does); `counts[p]` is the row popcount, as
/// `model.py` derives it.
pub fn search_block(seq: &[i8], patterns: &[i8], lengths: &[i32]) -> (Vec<i8>, Vec<i32>) {
    let n = lengths.len();
    let width = if n == 0 { 0 } else { patterns.len() / n };
    SearchEngine::from_rows(patterns, lengths, width).run_block(seq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::data::synthesize_genome;
    use crate::genome::encode::encode_seq;
    use crate::genome::patterns::PatternSpec;
    use crate::genome::search::search_naive;
    use crate::sim::Rng;

    fn row_dict(rows: &[&str], width: usize) -> PatternDict {
        let mut matrix = vec![PAD; rows.len() * width];
        let mut lengths = vec![0i32; rows.len()];
        for (p, r) in rows.iter().enumerate() {
            let e = encode_seq(r);
            matrix[p * width..p * width + e.len()].copy_from_slice(&e);
            lengths[p] = e.len() as i32;
        }
        PatternDict { matrix, lengths, width, n: rows.len() }
    }

    #[test]
    fn bank_packing_group_sizes() {
        // six length-15 patterns at ⌊64/15⌋ = 4 per bank → banks of 4 + 2
        let rows: Vec<String> = (0..6)
            .map(|p| (0..15).map(|i| "ACGT".as_bytes()[(p + i) % 4] as char).collect())
            .collect();
        let refs: Vec<&str> = rows.iter().map(|s| s.as_str()).collect();
        let d = row_dict(&refs, 15);
        let eng = SearchEngine::from_dict(&d);
        assert_eq!(eng.banks.len(), 2);
        assert_eq!(eng.banks[0].ids, vec![0, 1, 2, 3]);
        assert_eq!(eng.banks[1].ids, vec![4, 5]);
        assert_eq!(eng.max_len, 15);
        assert!(eng.long.is_empty());
    }

    #[test]
    fn packed_fields_do_not_interfere() {
        // two length-2 patterns in one bank; "AA" must not leak a partial
        // match into "AC"'s field across the shared shift
        let d = row_dict(&["AA", "AC"], 4);
        let g = vec![Chromosome { name: "t", seq: encode_seq("AAACAA") }];
        for threads in [1, 4] {
            let hits = search_engine(&g, &d, Strand::Forward, threads);
            let want = search_naive(&g, &d, Strand::Forward);
            assert_eq!(hits, want);
        }
    }

    #[test]
    fn engine_equals_naive_on_synthetic_genome() {
        let g = synthesize_genome(40_000, 3);
        let mut rng = Rng::new(12);
        let spec = PatternSpec { n_patterns: 32, ..Default::default() };
        let d = PatternDict::build(&spec, &g, &mut rng);
        for strand in [Strand::Forward, Strand::Reverse] {
            let want = search_naive(&g, &d, strand);
            for threads in [1, 4] {
                assert_eq!(search_engine(&g, &d, strand, threads), want, "{strand:?} x{threads}");
            }
        }
    }

    #[test]
    fn pattern_with_n_matches_text_n() {
        // literal equality: pattern N matches sequence N, same as the oracle
        let d = row_dict(&["GNN"], 4);
        let g = vec![Chromosome { name: "t", seq: encode_seq("ACGNNGT") }];
        let hits = search_engine(&g, &d, Strand::Forward, 1);
        assert_eq!(hits, search_naive(&g, &d, Strand::Forward));
        assert_eq!(hits.len(), 1);
        assert_eq!((hits[0].start, hits[0].end), (3, 5));
    }

    #[test]
    fn long_tail_patterns_use_literal_scan() {
        // width 80 ⇒ lengths above BANK_MAX_LEN go through LongPat
        let mut rng = Rng::new(77);
        let seq: Vec<i8> = (0..300).map(|_| rng.range_u64(0, 4) as i8).collect();
        let planted: String =
            seq[100..170].iter().map(|&c| "ACGT".as_bytes()[c as usize] as char).collect();
        let other: String = (0..66).map(|i| "ACGT".as_bytes()[i % 4] as char).collect();
        let d = row_dict(&[planted.as_str(), other.as_str()], 80);
        let g = vec![Chromosome { name: "t", seq }];
        let eng = SearchEngine::from_dict(&d);
        assert!(eng.banks.is_empty());
        assert_eq!(eng.long.len(), 2);
        for threads in [1, 4] {
            let hits = search_engine(&g, &d, Strand::Forward, threads);
            assert_eq!(hits, search_naive(&g, &d, Strand::Forward));
            assert!(hits.iter().any(|h| h.pattern_id == 0 && h.start == 101));
        }
    }

    #[test]
    fn empty_dict_and_empty_genome() {
        let d = PatternDict { matrix: vec![], lengths: vec![], width: 25, n: 0 };
        let g = synthesize_genome(1_000, 1);
        assert!(search_engine(&g, &d, Strand::Forward, 2).is_empty());
        let d2 = row_dict(&["ACGT"], 8);
        assert!(search_engine(&[], &d2, Strand::Forward, 2).is_empty());
        let empty_chrom = vec![Chromosome { name: "z", seq: vec![] }];
        assert!(search_engine(&empty_chrom, &d2, Strand::Forward, 2).is_empty());
    }

    #[test]
    fn search_block_matches_literal_equality_reference() {
        // padded chunk + padded block: the mask must reproduce the kernel's
        // literal-equality semantics for every row, padding rows included
        let g = synthesize_genome(9_000, 6);
        let chr = &g[0];
        let mut rng = Rng::new(2);
        let spec = PatternSpec { n_patterns: 6, ..Default::default() };
        let d = PatternDict::build(&spec, std::slice::from_ref(chr), &mut rng);
        let (patterns, lengths) = d.block(0, 8); // 6 real + 2 all-PAD rows
        let chunk = chr.seq.len() + 40;
        let mut seq = chr.seq.clone();
        seq.resize(chunk, PAD);

        let (mask, counts) = search_block(&seq, &patterns, &lengths);
        assert_eq!(mask.len(), 8 * chunk);
        for p in 0..8 {
            let m = lengths[p] as usize;
            let pat = &patterns[p * d.width..p * d.width + m];
            let mut want_count = 0;
            for i in 0..chunk {
                let want = i + m <= chunk && &seq[i..i + m] == pat;
                assert_eq!(mask[p * chunk + i] != 0, want, "row {p} pos {i}");
                want_count += want as i32;
            }
            assert_eq!(counts[p], want_count, "row {p}");
        }
        // the all-PAD padding rows match only the PAD tail
        assert!(counts[6] > 0 && counts[7] > 0);
        assert!(mask[6 * chunk..6 * chunk + chr.seq.len()].iter().all(|&b| b == 0));
    }

    #[test]
    fn run_block_zero_length_rows_match_everywhere() {
        // kernel semantics: lens = 0 leaves no active column, so every
        // window position is a hit; the chromosome search skips such rows,
        // exactly like the oracle
        let matrix = vec![PAD; 2 * 4];
        let lengths = vec![0i32, 0];
        let (mask, counts) = search_block(&[0, 1, 2, 3, 0], &matrix, &lengths);
        assert!(mask.iter().all(|&b| b == 1));
        assert_eq!(counts, vec![5, 5]);
        let d = PatternDict { matrix, lengths, width: 4, n: 2 };
        let g = vec![Chromosome { name: "t", seq: encode_seq("ACGT") }];
        assert!(search_engine(&g, &d, Strand::Forward, 1).is_empty());
        assert!(search_naive(&g, &d, Strand::Forward).is_empty());
    }

    #[test]
    fn search_block_empty_inputs() {
        let (mask, counts) = search_block(&[], &[], &[]);
        assert!(mask.is_empty() && counts.is_empty());
        let (mask, counts) = search_block(&[0, 1, 2, 3], &[], &[]);
        assert!(mask.is_empty() && counts.is_empty());
    }

    #[test]
    fn rare_probe_picks_scarce_symbol() {
        // A appears once at offset 2; everything else is T
        let syms: Vec<u8> = vec![3, 3, 0, 3, 3];
        assert_eq!(rare_probe(&syms), 2);
    }
}
