//! Pure-Rust naive search — the oracle the PJRT compute path is verified
//! against in integration tests (a third implementation, independent of
//! both the Pallas kernel and the jnp reference).

use super::data::Chromosome;
use super::hits::{Hit, Strand};
use super::patterns::PatternDict;

/// Scan every chromosome for every pattern on the given strand
/// (reverse-strand hits are reported at forward coordinates of the
/// reverse-complement match, consistent with the kernel+revcomp-dict path).
pub fn search_naive(genome: &[Chromosome], dict: &PatternDict, strand: Strand) -> Vec<Hit> {
    let effective = match strand {
        Strand::Forward => dict.clone(),
        Strand::Reverse => dict.revcomp(),
    };
    let mut hits = Vec::new();
    for (ci, chr) in genome.iter().enumerate() {
        for p in 0..effective.n {
            let pat = effective.pattern(p);
            if pat.is_empty() || pat.len() > chr.seq.len() {
                continue;
            }
            for (i, w) in chr.seq.windows(pat.len()).enumerate() {
                if w == pat {
                    hits.push(Hit {
                        chrom_idx: ci,
                        start: i + 1,
                        end: i + pat.len(),
                        pattern_id: p,
                        strand,
                    });
                }
            }
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::encode::{encode_seq, PAD};

    fn mini() -> (Vec<Chromosome>, PatternDict) {
        let chr = Chromosome { name: "chrT", seq: encode_seq("ACGTACGTTT") };
        let width = 6;
        // patterns: CGTA (at pos 2), TTT (at 8)
        let mut matrix = vec![PAD; 2 * width];
        matrix[..4].copy_from_slice(&encode_seq("CGTA"));
        matrix[width..width + 3].copy_from_slice(&encode_seq("TTT"));
        let dict = PatternDict { matrix, lengths: vec![4, 3], width, n: 2 };
        (vec![chr], dict)
    }

    #[test]
    fn forward_hits() {
        let (g, d) = mini();
        let hits = search_naive(&g, &d, Strand::Forward);
        assert_eq!(hits.len(), 2);
        assert_eq!((hits[0].start, hits[0].end, hits[0].pattern_id), (2, 5, 0));
        assert_eq!((hits[1].start, hits[1].end, hits[1].pattern_id), (8, 10, 1));
    }

    #[test]
    fn reverse_hits_via_revcomp() {
        let (g, d) = mini();
        // revcomp(CGTA)=TACG present at pos 3 (0-based 2? ACGTACGTTT:
        // TACG at 0-based 3) → start 4, end 7
        let hits = search_naive(&g, &d, Strand::Reverse);
        let rc_hit = hits.iter().find(|h| h.pattern_id == 0).unwrap();
        assert_eq!((rc_hit.start, rc_hit.end), (4, 7));
        // revcomp(TTT)=AAA absent
        assert!(hits.iter().all(|h| h.pattern_id != 1));
    }

    #[test]
    fn pattern_longer_than_chrom_skipped() {
        let chr = Chromosome { name: "t", seq: encode_seq("AC") };
        let mut matrix = vec![PAD; 6];
        matrix[..5].copy_from_slice(&encode_seq("ACGTA"));
        let dict = PatternDict { matrix, lengths: vec![5], width: 6, n: 1 };
        assert!(search_naive(&[chr], &dict, Strand::Forward).is_empty());
    }
}
