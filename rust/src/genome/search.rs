//! Pure-Rust naive search — the oracle both the packed engine
//! ([`engine`](super::engine), property-tested byte-identical in
//! `tests/genome_engine.rs`) and the PJRT compute path are verified
//! against (an implementation independent of the banks, the Pallas kernel
//! and the jnp reference).

use super::data::Chromosome;
use super::hits::{Hit, Strand};
use super::patterns::PatternDict;

/// Scan every chromosome for every pattern on the given strand
/// (reverse-strand hits are reported at forward coordinates of the
/// reverse-complement match, consistent with the kernel+revcomp-dict path).
///
/// The scan is a first-byte prefilter followed by a slice-equality tail
/// compare (which LLVM lowers to `memcmp`): on 4-letter DNA only ~1/4 of
/// windows survive the prefilter, so the oracle stays usable on real
/// chromosomes instead of paying an element-wise window compare at every
/// position. Hit order — chromosome, then pattern, then position — is
/// unchanged from the windows-based scan (asserted in tests).
pub fn search_naive(genome: &[Chromosome], dict: &PatternDict, strand: Strand) -> Vec<Hit> {
    let effective = match strand {
        Strand::Forward => dict.clone(),
        Strand::Reverse => dict.revcomp(),
    };
    let mut hits = Vec::new();
    for (ci, chr) in genome.iter().enumerate() {
        let seq: &[i8] = &chr.seq;
        for p in 0..effective.n {
            let pat = effective.pattern(p);
            if pat.is_empty() || pat.len() > seq.len() {
                continue;
            }
            let first = pat[0];
            let tail = &pat[1..];
            let m = pat.len();
            for i in 0..=(seq.len() - m) {
                if seq[i] == first && &seq[i + 1..i + m] == tail {
                    hits.push(Hit {
                        chrom_idx: ci,
                        start: i + 1,
                        end: i + m,
                        pattern_id: p,
                        strand,
                    });
                }
            }
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::encode::{encode_seq, PAD};

    fn mini() -> (Vec<Chromosome>, PatternDict) {
        let chr = Chromosome { name: "chrT", seq: encode_seq("ACGTACGTTT") };
        let width = 6;
        // patterns: CGTA (at pos 2), TTT (at 8)
        let mut matrix = vec![PAD; 2 * width];
        matrix[..4].copy_from_slice(&encode_seq("CGTA"));
        matrix[width..width + 3].copy_from_slice(&encode_seq("TTT"));
        let dict = PatternDict { matrix, lengths: vec![4, 3], width, n: 2 };
        (vec![chr], dict)
    }

    #[test]
    fn forward_hits() {
        let (g, d) = mini();
        let hits = search_naive(&g, &d, Strand::Forward);
        assert_eq!(hits.len(), 2);
        assert_eq!((hits[0].start, hits[0].end, hits[0].pattern_id), (2, 5, 0));
        assert_eq!((hits[1].start, hits[1].end, hits[1].pattern_id), (8, 10, 1));
    }

    #[test]
    fn reverse_hits_via_revcomp() {
        let (g, d) = mini();
        // revcomp(CGTA)=TACG present at pos 3 (0-based 2? ACGTACGTTT:
        // TACG at 0-based 3) → start 4, end 7
        let hits = search_naive(&g, &d, Strand::Reverse);
        let rc_hit = hits.iter().find(|h| h.pattern_id == 0).unwrap();
        assert_eq!((rc_hit.start, rc_hit.end), (4, 7));
        // revcomp(TTT)=AAA absent
        assert!(hits.iter().all(|h| h.pattern_id != 1));
    }

    #[test]
    fn pattern_longer_than_chrom_skipped() {
        let chr = Chromosome { name: "t", seq: encode_seq("AC") };
        let mut matrix = vec![PAD; 6];
        matrix[..5].copy_from_slice(&encode_seq("ACGTA"));
        let dict = PatternDict { matrix, lengths: vec![5], width: 6, n: 1 };
        assert!(search_naive(&[chr], &dict, Strand::Forward).is_empty());
    }

    #[test]
    fn single_base_pattern_hits_every_occurrence() {
        // the prefilter IS the whole match when the pattern is one base
        let chr = Chromosome { name: "t", seq: encode_seq("ATATA") };
        let mut matrix = vec![PAD; 4];
        matrix[..1].copy_from_slice(&encode_seq("A"));
        let dict = PatternDict { matrix, lengths: vec![1], width: 4, n: 1 };
        let hits = search_naive(&[chr], &dict, Strand::Forward);
        let starts: Vec<usize> = hits.iter().map(|h| h.start).collect();
        assert_eq!(starts, vec![1, 3, 5]);
    }

    #[test]
    fn prefilter_scan_matches_windows_reference() {
        // the prefilter + memcmp scan returns exactly what the plain
        // windows scan did, hit-for-hit and in the same order
        use crate::genome::patterns::PatternSpec;
        use crate::genome::synthesize_genome;
        use crate::sim::Rng;
        let g = synthesize_genome(20_000, 13);
        let mut rng = Rng::new(14);
        let spec = PatternSpec { n_patterns: 24, ..Default::default() };
        let dict = PatternDict::build(&spec, &g, &mut rng);
        for strand in [Strand::Forward, Strand::Reverse] {
            let fast = search_naive(&g, &dict, strand);
            // reference: the pre-optimisation element-wise windows scan
            let effective = match strand {
                Strand::Forward => dict.clone(),
                Strand::Reverse => dict.revcomp(),
            };
            let mut reference = Vec::new();
            for (ci, chr) in g.iter().enumerate() {
                for p in 0..effective.n {
                    let pat = effective.pattern(p);
                    if pat.is_empty() || pat.len() > chr.seq.len() {
                        continue;
                    }
                    for (i, w) in chr.seq.windows(pat.len()).enumerate() {
                        if w == pat {
                            reference.push(Hit {
                                chrom_idx: ci,
                                start: i + 1,
                                end: i + pat.len(),
                                pattern_id: p,
                                strand,
                            });
                        }
                    }
                }
            }
            assert!(!fast.is_empty() || reference.is_empty());
            assert_eq!(fast, reference);
        }
    }
}
