//! Nucleotide encoding shared with the Pallas kernel:
//! A=0, C=1, G=2, T=3, N=4; pattern padding = -1.

pub const BASE_A: i8 = 0;
pub const BASE_C: i8 = 1;
pub const BASE_G: i8 = 2;
pub const BASE_T: i8 = 3;
pub const BASE_N: i8 = 4;
/// Pattern-matrix padding sentinel (must match kernels/genome_match.py).
pub const PAD: i8 = -1;

/// Encode one base character (case-insensitive); unknown characters encode
/// as N, as Bioconductor does for ambiguity codes.
pub fn encode_base(c: u8) -> i8 {
    match c.to_ascii_uppercase() {
        b'A' => BASE_A,
        b'C' => BASE_C,
        b'G' => BASE_G,
        b'T' => BASE_T,
        _ => BASE_N,
    }
}

/// Decode to a character.
pub fn decode_base(b: i8) -> char {
    match b {
        BASE_A => 'A',
        BASE_C => 'C',
        BASE_G => 'G',
        BASE_T => 'T',
        _ => 'N',
    }
}

pub fn encode_seq(s: &str) -> Vec<i8> {
    s.bytes().map(encode_base).collect()
}

pub fn decode_seq(v: &[i8]) -> String {
    v.iter().map(|&b| decode_base(b)).collect()
}

/// Reverse complement (N maps to N) — used to search the reverse strand
/// with the same forward kernel.
pub fn revcomp(v: &[i8]) -> Vec<i8> {
    v.iter()
        .rev()
        .map(|&b| match b {
            BASE_A => BASE_T,
            BASE_T => BASE_A,
            BASE_C => BASE_G,
            BASE_G => BASE_C,
            other => other,
        })
        .collect()
}

/// DNA packed to 2-bit codes — 32 bases per `u64` word — with an **N-run
/// side index**: two bits cannot represent the fifth symbol, so positions
/// of non-ACGT bases are stored as sorted, disjoint `[start, end)` runs
/// alongside the words (real assemblies hold Ns in a handful of long gap
/// runs, so the index is tiny). The packed form is what the search engine
/// scans: 4x less memory traffic than the `i8` sequence, and the run index
/// restores exact `N` semantics at decode time.
#[derive(Debug, Clone)]
pub struct PackedSeq {
    words: Vec<u64>,
    len: usize,
    n_runs: Vec<(usize, usize)>,
}

impl PackedSeq {
    /// Pack an encoded sequence (`encode_seq` output). Codes outside
    /// `0..=3` (i.e. `N`) pack as 0 in the words and are recorded in the
    /// run index.
    pub fn pack(seq: &[i8]) -> Self {
        let mut words = vec![0u64; seq.len().div_ceil(32)];
        let mut n_runs: Vec<(usize, usize)> = Vec::new();
        let mut run_start: Option<usize> = None;
        for (i, &c) in seq.iter().enumerate() {
            if (0..=3).contains(&c) {
                words[i >> 5] |= (c as u64) << ((i & 31) << 1);
                if let Some(s) = run_start.take() {
                    n_runs.push((s, i));
                }
            } else if run_start.is_none() {
                run_start = Some(i);
            }
        }
        if let Some(s) = run_start {
            n_runs.push((s, seq.len()));
        }
        Self { words, len: seq.len(), n_runs }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The sorted, disjoint `[start, end)` runs of non-ACGT positions.
    pub fn n_runs(&self) -> &[(usize, usize)] {
        &self.n_runs
    }

    /// Does `[start, end)` contain any non-ACGT position?
    pub fn has_n(&self, start: usize, end: usize) -> bool {
        let i = self.n_runs.partition_point(|&(_, e)| e <= start);
        self.n_runs.get(i).is_some_and(|&(s, _)| s < end)
    }

    /// Decode `[start, end)` into `buf` as codes `0..=4` (4 = N): bulk
    /// 2-bit extraction — one word load yields up to 32 codes — then the
    /// overlapping N-runs are painted back in.
    pub fn decode_range(&self, start: usize, end: usize, buf: &mut Vec<u8>) {
        debug_assert!(start <= end && end <= self.len);
        buf.clear();
        buf.reserve(end - start);
        let mut i = start;
        while i < end {
            let mut w = self.words[i >> 5] >> ((i & 31) << 1);
            let take = (32 - (i & 31)).min(end - i);
            for _ in 0..take {
                buf.push((w & 3) as u8);
                w >>= 2;
            }
            i += take;
        }
        let mut r = self.n_runs.partition_point(|&(_, e)| e <= start);
        while let Some(&(s, e)) = self.n_runs.get(r) {
            if s >= end {
                break;
            }
            for b in &mut buf[s.max(start) - start..e.min(end) - start] {
                *b = BASE_N as u8;
            }
            r += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = "ACGTNacgtn";
        let e = encode_seq(s);
        assert_eq!(e, vec![0, 1, 2, 3, 4, 0, 1, 2, 3, 4]);
        assert_eq!(decode_seq(&e), "ACGTNACGTN");
    }

    #[test]
    fn unknown_encodes_as_n() {
        assert_eq!(encode_base(b'R'), BASE_N);
        assert_eq!(encode_base(b'-'), BASE_N);
    }

    #[test]
    fn revcomp_basic() {
        // revcomp(ACGT) = ACGT; revcomp(AACG) = CGTT
        assert_eq!(revcomp(&encode_seq("ACGT")), encode_seq("ACGT"));
        assert_eq!(revcomp(&encode_seq("AACG")), encode_seq("CGTT"));
        assert_eq!(revcomp(&encode_seq("AN")), encode_seq("NT"));
    }

    #[test]
    fn revcomp_involution() {
        let s = encode_seq("ACGTTGCANNGT");
        assert_eq!(revcomp(&revcomp(&s)), s);
    }

    #[test]
    fn packed_roundtrip_with_n_runs() {
        let seq = encode_seq("ACGTNNACGNTTTN");
        let p = PackedSeq::pack(&seq);
        assert_eq!(p.len(), seq.len());
        assert_eq!(p.n_runs(), &[(4, 6), (9, 10), (13, 14)]);
        let mut buf = Vec::new();
        p.decode_range(0, seq.len(), &mut buf);
        let want: Vec<u8> = seq.iter().map(|&c| c as u8).collect();
        assert_eq!(buf, want);
    }

    #[test]
    fn packed_codes_span_word_boundaries() {
        // 70 bases > two u64 words; every code must survive the packing
        let seq: Vec<i8> = (0..70).map(|i| (i % 4) as i8).collect();
        let p = PackedSeq::pack(&seq);
        assert!(p.n_runs().is_empty());
        let mut buf = Vec::new();
        p.decode_range(0, 70, &mut buf);
        assert_eq!(buf, seq.iter().map(|&c| c as u8).collect::<Vec<_>>());
        p.decode_range(30, 40, &mut buf);
        assert_eq!(buf, (30..40).map(|i| (i % 4) as u8).collect::<Vec<_>>());
        // unaligned window straddling the 32-base word boundary
        p.decode_range(31, 33, &mut buf);
        assert_eq!(buf, vec![3, 0]);
    }

    #[test]
    fn packed_has_n_windows() {
        let seq = encode_seq("ACGTNNACGT");
        let p = PackedSeq::pack(&seq);
        assert!(!p.has_n(0, 4));
        assert!(p.has_n(0, 5));
        assert!(p.has_n(3, 7));
        assert!(p.has_n(5, 6));
        assert!(!p.has_n(6, 10));
        assert!(!p.has_n(4, 4)); // empty window
    }

    #[test]
    fn packed_decode_partial_run_overlap() {
        // run (4, 8); decode windows clipping it on each side
        let seq = encode_seq("ACGTNNNNACGT");
        let p = PackedSeq::pack(&seq);
        let mut buf = Vec::new();
        p.decode_range(2, 6, &mut buf);
        assert_eq!(buf, vec![2, 3, 4, 4]); // G T N N
        p.decode_range(6, 10, &mut buf);
        assert_eq!(buf, vec![4, 4, 0, 1]); // N N A C
    }

    #[test]
    fn packed_empty_and_all_n() {
        let p = PackedSeq::pack(&[]);
        assert!(p.is_empty() && p.n_runs().is_empty());
        let mut buf = vec![9u8];
        p.decode_range(0, 0, &mut buf);
        assert!(buf.is_empty());

        let p = PackedSeq::pack(&encode_seq("NNN"));
        assert_eq!(p.n_runs(), &[(0, 3)]);
        p.decode_range(0, 3, &mut buf);
        assert_eq!(buf, vec![4, 4, 4]);
    }
}
