//! Nucleotide encoding shared with the Pallas kernel:
//! A=0, C=1, G=2, T=3, N=4; pattern padding = -1.

pub const BASE_A: i8 = 0;
pub const BASE_C: i8 = 1;
pub const BASE_G: i8 = 2;
pub const BASE_T: i8 = 3;
pub const BASE_N: i8 = 4;
/// Pattern-matrix padding sentinel (must match kernels/genome_match.py).
pub const PAD: i8 = -1;

/// Encode one base character (case-insensitive); unknown characters encode
/// as N, as Bioconductor does for ambiguity codes.
pub fn encode_base(c: u8) -> i8 {
    match c.to_ascii_uppercase() {
        b'A' => BASE_A,
        b'C' => BASE_C,
        b'G' => BASE_G,
        b'T' => BASE_T,
        _ => BASE_N,
    }
}

/// Decode to a character.
pub fn decode_base(b: i8) -> char {
    match b {
        BASE_A => 'A',
        BASE_C => 'C',
        BASE_G => 'G',
        BASE_T => 'T',
        _ => 'N',
    }
}

pub fn encode_seq(s: &str) -> Vec<i8> {
    s.bytes().map(encode_base).collect()
}

pub fn decode_seq(v: &[i8]) -> String {
    v.iter().map(|&b| decode_base(b)).collect()
}

/// Reverse complement (N maps to N) — used to search the reverse strand
/// with the same forward kernel.
pub fn revcomp(v: &[i8]) -> Vec<i8> {
    v.iter()
        .rev()
        .map(|&b| match b {
            BASE_A => BASE_T,
            BASE_T => BASE_A,
            BASE_C => BASE_G,
            BASE_G => BASE_C,
            other => other,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let s = "ACGTNacgtn";
        let e = encode_seq(s);
        assert_eq!(e, vec![0, 1, 2, 3, 4, 0, 1, 2, 3, 4]);
        assert_eq!(decode_seq(&e), "ACGTNACGTN");
    }

    #[test]
    fn unknown_encodes_as_n() {
        assert_eq!(encode_base(b'R'), BASE_N);
        assert_eq!(encode_base(b'-'), BASE_N);
    }

    #[test]
    fn revcomp_basic() {
        // revcomp(ACGT) = ACGT; revcomp(AACG) = CGTT
        assert_eq!(revcomp(&encode_seq("ACGT")), encode_seq("ACGT"));
        assert_eq!(revcomp(&encode_seq("AACG")), encode_seq("CGTT"));
        assert_eq!(revcomp(&encode_seq("AN")), encode_seq("NT"));
    }

    #[test]
    fn revcomp_involution() {
        let s = encode_seq("ACGTTGCANNGT");
        assert_eq!(revcomp(&revcomp(&s)), s);
    }
}
