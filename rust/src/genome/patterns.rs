//! Pattern dictionaries: the paper's "list of 5000 genome patterns each of
//! which is a short nucleotide sequence of 15 to 25 bases".

use super::data::Chromosome;
use super::encode::{revcomp, PAD};
use crate::sim::Rng;

/// How to build a dictionary.
#[derive(Debug, Clone, Copy)]
pub struct PatternSpec {
    pub n_patterns: usize,
    pub min_len: usize,
    pub max_len: usize,
    /// Fraction of patterns planted from the genome (guaranteed hits).
    pub planted_frac: f64,
    /// Matrix width (the AOT kernel's WIDTH).
    pub width: usize,
}

impl Default for PatternSpec {
    fn default() -> Self {
        Self { n_patterns: 5000, min_len: 15, max_len: 25, planted_frac: 0.5, width: 25 }
    }
}

/// A dictionary in kernel layout.
#[derive(Debug, Clone)]
pub struct PatternDict {
    /// Row-major [n_patterns x width], PAD-padded.
    pub matrix: Vec<i8>,
    pub lengths: Vec<i32>,
    pub width: usize,
    /// pattern ids (their dictionary index); names render as "patternN".
    pub n: usize,
}

impl PatternDict {
    /// Build from a genome: planted patterns are sampled from random
    /// chromosome positions (avoiding Ns), the rest are random sequences.
    pub fn build(spec: &PatternSpec, genome: &[Chromosome], rng: &mut Rng) -> Self {
        assert!(spec.min_len >= 1 && spec.max_len <= spec.width);
        assert!(spec.min_len <= spec.max_len);
        let mut matrix = vec![PAD; spec.n_patterns * spec.width];
        let mut lengths = vec![0i32; spec.n_patterns];
        for p in 0..spec.n_patterns {
            let len = rng.range_usize(spec.min_len, spec.max_len + 1);
            lengths[p] = len as i32;
            let row = &mut matrix[p * spec.width..(p + 1) * spec.width];
            let planted = rng.chance(spec.planted_frac) && !genome.is_empty();
            if planted {
                // sample a window from a random chromosome (N allowed only
                // if sampling fails repeatedly)
                let mut placed = false;
                for _ in 0..16 {
                    let chr = rng.pick(genome);
                    if chr.seq.len() < len {
                        continue;
                    }
                    let start = rng.range_usize(0, chr.seq.len() - len + 1);
                    let window = &chr.seq[start..start + len];
                    if window.iter().all(|&b| b < 4) {
                        row[..len].copy_from_slice(window);
                        placed = true;
                        break;
                    }
                }
                if placed {
                    continue;
                }
            }
            for slot in row.iter_mut().take(len) {
                *slot = rng.range_u64(0, 4) as i8;
            }
        }
        Self { matrix, lengths, width: spec.width, n: spec.n_patterns }
    }

    pub fn row(&self, p: usize) -> &[i8] {
        &self.matrix[p * self.width..(p + 1) * self.width]
    }

    pub fn pattern(&self, p: usize) -> &[i8] {
        &self.row(p)[..self.lengths[p] as usize]
    }

    /// The reverse-complement dictionary (for reverse-strand search with
    /// the same forward kernel).
    pub fn revcomp(&self) -> Self {
        let mut matrix = vec![PAD; self.matrix.len()];
        for p in 0..self.n {
            let rc = revcomp(self.pattern(p));
            matrix[p * self.width..p * self.width + rc.len()].copy_from_slice(&rc);
        }
        Self { matrix, lengths: self.lengths.clone(), width: self.width, n: self.n }
    }

    /// Slice a block of patterns [start, start+count) into a padded
    /// (matrix, lengths) pair of exactly `count` rows (short blocks pad with
    /// empty never-matching rows of length `width`+sentinel).
    pub fn block(&self, start: usize, count: usize) -> (Vec<i8>, Vec<i32>) {
        let mut m = vec![PAD; count * self.width];
        // length `width` with all-PAD rows never matches any real base
        let mut l = vec![self.width as i32; count];
        for i in 0..count {
            let p = start + i;
            if p < self.n {
                m[i * self.width..(i + 1) * self.width].copy_from_slice(self.row(p));
                l[i] = self.lengths[p];
            }
        }
        (m, l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::data::synthesize_genome;

    fn dict() -> (Vec<Chromosome>, PatternDict) {
        let g = synthesize_genome(50_000, 3);
        let mut rng = Rng::new(11);
        let spec = PatternSpec { n_patterns: 100, ..Default::default() };
        let d = PatternDict::build(&spec, &g, &mut rng);
        (g, d)
    }

    #[test]
    fn lengths_in_paper_range() {
        let (_, d) = dict();
        assert!(d.lengths.iter().all(|&l| (15..=25).contains(&l)));
    }

    #[test]
    fn rows_padded_with_sentinel() {
        let (_, d) = dict();
        for p in 0..d.n {
            let row = d.row(p);
            let len = d.lengths[p] as usize;
            assert!(row[..len].iter().all(|&b| (0..4).contains(&b)));
            assert!(row[len..].iter().all(|&b| b == PAD));
        }
    }

    #[test]
    fn planted_patterns_exist_in_genome() {
        let (g, d) = dict();
        // at least a third of patterns must be findable (planted_frac 0.5
        // minus collisions)
        let mut found = 0;
        for p in 0..d.n {
            let pat = d.pattern(p);
            if g.iter().any(|c| {
                c.seq.windows(pat.len()).any(|w| w == pat)
            }) {
                found += 1;
            }
        }
        assert!(found >= d.n / 3, "only {found}/{} found", d.n);
    }

    #[test]
    fn revcomp_dict_consistent() {
        let (_, d) = dict();
        let rc = d.revcomp();
        for p in 0..d.n {
            assert_eq!(rc.pattern(p), revcomp(d.pattern(p)).as_slice());
        }
    }

    #[test]
    fn block_slicing_pads_tail() {
        let (_, d) = dict();
        let (m, l) = d.block(96, 8); // 4 real + 4 padding rows
        assert_eq!(m.len(), 8 * d.width);
        assert_eq!(l.len(), 8);
        assert_eq!(&m[0..d.width], d.row(96));
        // padded rows: all PAD with full width length -> can never match
        assert!(m[4 * d.width..].iter().all(|&b| b == PAD));
        assert!(l[4..].iter().all(|&x| x == d.width as i32));
    }

    #[test]
    fn deterministic_per_seed() {
        let g = synthesize_genome(10_000, 5);
        let spec = PatternSpec { n_patterns: 20, ..Default::default() };
        let a = PatternDict::build(&spec, &g, &mut Rng::new(1));
        let b = PatternDict::build(&spec, &g, &mut Rng::new(1));
        assert_eq!(a.matrix, b.matrix);
    }
}
