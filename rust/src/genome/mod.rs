//! Genome-searching workload: synthetic *C. elegans*-scale chromosomes,
//! pattern dictionaries, hit records (Fig. 14) and a pure-Rust reference
//! search used as the oracle for the PJRT compute path.
//!
//! Substitution note (DESIGN.md): the paper uses Bioconductor BSgenome
//! ce2/ce6/ce10 data. Without network access we synthesise seeded
//! chromosomes with the same alphabet, the same seven-chromosome layout
//! (chrI..chrV, chrX, chrM) and the paper's pattern-length distribution
//! (15-25 nt); the compute path is identical.

pub mod data;
pub mod encode;
pub mod hits;
pub mod patterns;
pub mod search;

pub use data::{synthesize_genome, Chromosome};
pub use encode::{decode_seq, encode_base, encode_seq, revcomp, BASE_N, PAD};
pub use hits::{collate_hits, format_hits, Hit, Strand};
pub use patterns::{PatternDict, PatternSpec};
pub use search::search_naive;
