//! Genome-searching workload: synthetic *C. elegans*-scale chromosomes,
//! pattern dictionaries, hit records (Fig. 14), the packed chunk-parallel
//! search engine ([`engine`]) that makes paper-scale dictionaries (5000
//! patterns of 15-25 nt) tractable in pure Rust, and the naive reference
//! search kept as the oracle both the engine and the PJRT compute path are
//! verified against.
//!
//! Substitution note (DESIGN.md): the paper uses Bioconductor BSgenome
//! ce2/ce6/ce10 data. Without network access we synthesise seeded
//! chromosomes with the same alphabet, the same seven-chromosome layout
//! (chrI..chrV, chrX, chrM) and the paper's pattern-length distribution
//! (15-25 nt); the compute path is identical.

pub mod data;
pub mod encode;
pub mod engine;
pub mod hits;
pub mod patterns;
pub mod search;

pub use data::{synthesize_genome, Chromosome};
pub use encode::{decode_seq, encode_base, encode_seq, revcomp, PackedSeq, BASE_N, PAD};
pub use engine::{search_block, search_engine, search_engine_both, SearchEngine};
pub use hits::{collate_hits, format_hits, Hit, Strand};
pub use patterns::{PatternDict, PatternSpec};
pub use search::search_naive;
