//! Seeded synthetic genome: seven chromosomes named like *C. elegans*
//! (chrI..chrV, chrX, chrM) with proportional lengths scaled to a total
//! budget, plus redundant-copy amplification (the paper replicates input
//! data on each node "to obtain a sizeable input").

use super::encode::{BASE_N, PAD};
use crate::sim::Rng;

/// One synthetic chromosome.
#[derive(Debug, Clone)]
pub struct Chromosome {
    pub name: &'static str,
    /// Encoded sequence (A=0..T=3 with occasional N).
    pub seq: Vec<i8>,
}

/// Real ce10 chromosome lengths (bp), used as proportions.
const CE_PROPORTIONS: [(&str, f64); 7] = [
    ("chrI", 15_072_423.0),
    ("chrII", 15_279_345.0),
    ("chrIII", 13_783_700.0),
    ("chrIV", 17_493_793.0),
    ("chrV", 20_924_149.0),
    ("chrX", 17_718_866.0),
    ("chrM", 13_794.0),
];

/// Synthesise the seven-chromosome genome with a total of ~`total_bases`
/// bases, deterministically from `seed`. A small N fraction (~0.1 %)
/// mimics assembly gaps.
pub fn synthesize_genome(total_bases: usize, seed: u64) -> Vec<Chromosome> {
    assert!(total_bases >= 7, "need at least one base per chromosome");
    let total_prop: f64 = CE_PROPORTIONS.iter().map(|(_, p)| p).sum();
    let mut rng = Rng::new(seed);
    CE_PROPORTIONS
        .iter()
        .map(|(name, prop)| {
            let len = ((prop / total_prop) * total_bases as f64).round().max(1.0) as usize;
            let mut chr_rng = rng.fork(fxhash(name));
            let seq = (0..len)
                .map(|_| {
                    if chr_rng.chance(0.001) {
                        BASE_N
                    } else {
                        chr_rng.range_u64(0, 4) as i8
                    }
                })
                .collect();
            Chromosome { name, seq }
        })
        .collect()
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

impl Chromosome {
    /// Split into fixed-size chunks with `overlap` bases of overlap so no
    /// cross-boundary window is missed; the final chunk is padded with PAD
    /// (never matches). Returns (chunk_start, padded_chunk) pairs.
    pub fn chunks(&self, chunk: usize, overlap: usize) -> Vec<(usize, Vec<i8>)> {
        assert!(chunk > overlap, "chunk must exceed overlap");
        let stride = chunk - overlap;
        let mut out = Vec::new();
        let mut start = 0;
        loop {
            let end = (start + chunk).min(self.seq.len());
            let mut c = self.seq[start..end].to_vec();
            c.resize(chunk, PAD);
            out.push((start, c));
            if end == self.seq.len() {
                break;
            }
            start += stride;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_chromosomes_proportional() {
        let g = synthesize_genome(100_000, 1);
        assert_eq!(g.len(), 7);
        let names: Vec<_> = g.iter().map(|c| c.name).collect();
        assert_eq!(names, vec!["chrI", "chrII", "chrIII", "chrIV", "chrV", "chrX", "chrM"]);
        let v = g.iter().find(|c| c.name == "chrV").unwrap();
        let m = g.iter().find(|c| c.name == "chrM").unwrap();
        assert!(v.seq.len() > 50 * m.seq.len().max(1));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synthesize_genome(10_000, 42);
        let b = synthesize_genome(10_000, 42);
        assert_eq!(a[0].seq, b[0].seq);
        let c = synthesize_genome(10_000, 43);
        assert_ne!(a[0].seq, c[0].seq);
    }

    #[test]
    fn bases_in_range() {
        let g = synthesize_genome(20_000, 7);
        for c in &g {
            assert!(c.seq.iter().all(|&b| (0..=4).contains(&b)));
        }
    }

    #[test]
    fn n_fraction_small() {
        let g = synthesize_genome(200_000, 9);
        let total: usize = g.iter().map(|c| c.seq.len()).sum();
        let ns: usize =
            g.iter().map(|c| c.seq.iter().filter(|&&b| b == BASE_N).count()).sum();
        let frac = ns as f64 / total as f64;
        assert!(frac < 0.01, "N fraction {frac}");
    }

    #[test]
    fn chunks_cover_and_overlap() {
        let chr = Chromosome { name: "t", seq: (0..100).map(|i| (i % 4) as i8).collect() };
        let chunks = chr.chunks(40, 10);
        assert_eq!(chunks[0].0, 0);
        assert_eq!(chunks[1].0, 30);
        // overlap: last 10 of chunk 0 == first 10 of chunk 1
        assert_eq!(&chunks[0].1[30..40], &chunks[1].1[0..10]);
        // all chunks padded to length
        assert!(chunks.iter().all(|(_, c)| c.len() == 40));
        // final chunk reaches the end
        let (last_start, _) = *chunks.last().unwrap();
        assert!(last_start + 40 >= 100);
    }

    #[test]
    fn chunk_padding_is_pad() {
        let chr = Chromosome { name: "t", seq: vec![0; 50] };
        let chunks = chr.chunks(40, 10);
        let (_, last) = chunks.last().unwrap();
        assert_eq!(last[last.len() - 1], PAD);
    }
}
