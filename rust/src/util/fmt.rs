//! Formatting helpers for the paper's units: `hh:mm:ss`, seconds with
//! sub-second precision, and KB-denominated data sizes (the paper quotes
//! sizes like `2^19 KB`).

/// Format a duration in seconds as `hh:mm:ss` (paper table format).
pub fn hms(seconds: f64) -> String {
    let total = seconds.round() as i64;
    let (h, rem) = (total / 3600, total % 3600);
    let (m, s) = (rem / 60, rem % 60);
    format!("{h:02}:{m:02}:{s:02}")
}

/// Format a duration in seconds as `hh:mm:ss.mmm` when sub-second detail
/// matters (reinstating times are fractions of a second).
pub fn hms_ms(seconds: f64) -> String {
    let whole = seconds.floor();
    let ms = ((seconds - whole) * 1000.0).round() as i64;
    format!("{}.{ms:03}", hms(whole))
}

/// Human-readable seconds: chooses ms / s / m / h scale.
pub fn secs(seconds: f64) -> String {
    if seconds < 1.0 {
        format!("{:.0} ms", seconds * 1e3)
    } else if seconds < 120.0 {
        format!("{seconds:.2} s")
    } else if seconds < 7200.0 {
        format!("{:.1} min", seconds / 60.0)
    } else {
        format!("{:.2} h", seconds / 3600.0)
    }
}

/// Format a size given in **kilobytes** (the paper's unit) as a power of two
/// plus a human-readable suffix, e.g. `2^19 KB (512 MiB)`.
pub fn kb_pow2(kb: u64) -> String {
    let log = (kb as f64).log2();
    let human = human_bytes(kb.saturating_mul(1024));
    if (log - log.round()).abs() < 1e-9 {
        format!("2^{} KB ({human})", log.round() as u32)
    } else {
        format!("{kb} KB ({human})")
    }
}

/// Human-readable byte count.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hms_basic() {
        assert_eq!(hms(0.0), "00:00:00");
        assert_eq!(hms(3661.0), "01:01:01");
        assert_eq!(hms(5.0 * 3600.0 + 27.0 * 60.0 + 15.0), "05:27:15");
    }

    #[test]
    fn hms_rounds() {
        assert_eq!(hms(59.6), "00:01:00");
    }

    #[test]
    fn hms_ms_subsecond() {
        assert_eq!(hms_ms(0.47), "00:00:00.470");
        assert_eq!(hms_ms(65.038), "00:01:05.038");
    }

    #[test]
    fn secs_scales() {
        assert_eq!(secs(0.5), "500 ms");
        assert_eq!(secs(2.0), "2.00 s");
        assert!(secs(600.0).ends_with("min"));
        assert!(secs(10_000.0).ends_with("h"));
    }

    #[test]
    fn kb_pow2_exact() {
        assert_eq!(kb_pow2(1 << 19), "2^19 KB (512.0 MiB)");
        assert!(kb_pow2(1000).starts_with("1000 KB"));
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(10), "10 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(1 << 30), "1.0 GiB");
    }
}
