//! Minimal declarative CLI argument parser.
//!
//! The vendored crate set has no `clap`, so this module provides the small
//! subset the binaries need: subcommands, `--flag`, `--key value` /
//! `--key=value` options with defaults, typed accessors, and generated help.

use std::collections::BTreeMap;

/// Description of one option for help output and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// A parsed command line: positional arguments plus resolved options.
#[derive(Debug, Default, Clone)]
pub struct Parsed {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Parsed {
    /// String option (falls back to the declared default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Typed option accessor; parse errors surface as anyhow errors.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opts.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{name}={v}: {e}")),
        }
    }

    /// Required typed option.
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.get_parse::<T>(name)?
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// A command (or subcommand) specification.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    /// Parse `args` (no program name) against this command.
    pub fn parse(&self, args: &[String]) -> anyhow::Result<Parsed> {
        let mut out = Parsed::default();
        for spec in &self.opts {
            if let Some(d) = spec.default {
                out.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key} for `{}`", self.name))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{key} takes no value");
                    }
                    out.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{key} expects a value"))?
                        }
                    };
                    out.opts.insert(key, val);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Render help text for this command.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let def = match o.default {
                Some(d) => format!(" [default: {d}]"),
                None if !o.is_flag => " [required]".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("  --{}{kind}\t{}{def}\n", o.name, o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("run", "run things")
            .opt("cluster", "placentia", "cluster preset")
            .opt("trials", "30", "trial count")
            .opt_req("id", "experiment id")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn defaults_apply() {
        let p = cmd().parse(&args(&["--id", "fig8"])).unwrap();
        assert_eq!(p.get("cluster"), Some("placentia"));
        assert_eq!(p.req::<u32>("trials").unwrap(), 30);
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn equals_and_space_forms() {
        let p = cmd().parse(&args(&["--id=t1", "--trials", "7", "--verbose"])).unwrap();
        assert_eq!(p.get("id"), Some("t1"));
        assert_eq!(p.req::<u32>("trials").unwrap(), 7);
        assert!(p.flag("verbose"));
    }

    #[test]
    fn positional_collected() {
        let p = cmd().parse(&args(&["--id", "x", "extra1", "extra2"])).unwrap();
        assert_eq!(p.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&args(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&args(&["--id"])).is_err());
    }

    #[test]
    fn missing_required_surfaces_on_req() {
        let p = cmd().parse(&args(&[])).unwrap();
        assert!(p.req::<String>("id").is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&args(&["--verbose=1"])).is_err());
    }

    #[test]
    fn bad_parse_reports_option() {
        let p = cmd().parse(&args(&["--id", "x", "--trials", "NaNope"])).unwrap();
        let err = p.req::<u32>("trials").unwrap_err().to_string();
        assert!(err.contains("trials"), "{err}");
    }

    #[test]
    fn help_lists_options() {
        let h = cmd().help();
        assert!(h.contains("--cluster"));
        assert!(h.contains("[default: placentia]"));
        assert!(h.contains("[required]"));
    }
}
