//! Small shared utilities: time/byte formatting, CLI parsing, config files.

pub mod cli;
pub mod conf;
pub mod fmt;
