//! Minimal TOML-subset configuration parser.
//!
//! The vendored crate set has no `serde`/`toml`, so experiments and cluster
//! descriptions are loaded with this hand-rolled parser. Supported subset:
//! `[table]` headers, `key = value` with string / integer / float / bool /
//! flat arrays, `#` comments, and underscored integer literals (`1_000`).

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A configuration document: `table.key -> Value` (root table keys have no
/// prefix).
#[derive(Debug, Default, Clone)]
pub struct Conf {
    entries: BTreeMap<String, Value>,
}

impl Conf {
    /// Parse a document; errors carry the line number.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut entries = BTreeMap::new();
        let mut table = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated table header", lineno + 1))?;
                table = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected `key = value`", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                anyhow::bail!("line {}: empty key", lineno + 1);
            }
            let full = if table.is_empty() { key.to_string() } else { format!("{table}.{key}") };
            let value = parse_value(val.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            entries.insert(full, value);
        }
        Ok(Self { entries })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Keys of one table (without the table prefix).
    pub fn table_keys(&self, table: &str) -> Vec<String> {
        let prefix = format!("{table}.");
        self.entries
            .keys()
            .filter_map(|k| k.strip_prefix(&prefix).map(|s| s.to_string()))
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<Value> {
    if s.is_empty() {
        anyhow::bail!("empty value");
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        return Ok(Value::Str(body.to_string()));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or_else(|| anyhow::anyhow!("unterminated array"))?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_top_level(body)
            .into_iter()
            .map(|p| parse_value(p.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("cannot parse value `{s}`")
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# experiment config
name = "fig8"
trials = 30
noise = 0.03          # lognormal sigma
enabled = true
sizes = [1, 2, 3]

[cluster]
preset = "placentia"
latency_us = 8.5
tags = ["infiniband", "acenet"]
"#;

    #[test]
    fn parses_scalars() {
        let c = Conf::parse(DOC).unwrap();
        assert_eq!(c.get("name").unwrap().as_str(), Some("fig8"));
        assert_eq!(c.get("trials").unwrap().as_int(), Some(30));
        assert_eq!(c.get("noise").unwrap().as_float(), Some(0.03));
        assert_eq!(c.get("enabled").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_tables() {
        let c = Conf::parse(DOC).unwrap();
        assert_eq!(c.str_or("cluster.preset", "x"), "placentia");
        assert_eq!(c.float_or("cluster.latency_us", 0.0), 8.5);
    }

    #[test]
    fn parses_arrays() {
        let c = Conf::parse(DOC).unwrap();
        let arr = c.get("sizes").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_int(), Some(3));
        let tags = c.get("cluster.tags").unwrap().as_array().unwrap();
        assert_eq!(tags[0].as_str(), Some("infiniband"));
    }

    #[test]
    fn defaults_on_missing() {
        let c = Conf::parse(DOC).unwrap();
        assert_eq!(c.int_or("missing", 7), 7);
        assert_eq!(c.str_or("cluster.missing", "d"), "d");
        assert!(c.bool_or("missing", true));
    }

    #[test]
    fn int_coerces_to_float() {
        let c = Conf::parse("x = 4").unwrap();
        assert_eq!(c.float_or("x", 0.0), 4.0);
    }

    #[test]
    fn underscored_ints() {
        let c = Conf::parse("n = 1_048_576").unwrap();
        assert_eq!(c.int_or("n", 0), 1 << 20);
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Conf::parse(r##"s = "a#b""##).unwrap();
        assert_eq!(c.get("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Conf::parse("a = 1\nbroken").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn table_keys_listed() {
        let c = Conf::parse(DOC).unwrap();
        let keys = c.table_keys("cluster");
        assert!(keys.contains(&"preset".to_string()));
        assert!(keys.contains(&"latency_us".to_string()));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(Conf::parse("a = ").is_err());
        assert!(Conf::parse("a = \"open").is_err());
        assert!(Conf::parse("a = [1, 2").is_err());
        assert!(Conf::parse("[open").is_err());
    }
}
